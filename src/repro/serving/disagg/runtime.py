"""PD-disaggregated cluster runtime: real engines + autoscaler + migration.

This is the real-engine counterpart of the §5.4 policy that previously
lived only in the discrete-event simulator: phase-tagged pools of
:class:`InstanceEngine` serve prefill and decode separately; finished
prefills freeze their KV pages and migrate them to a decode instance over
the topology-modelled network; the :class:`Autoscaler` drives

  * prefill scale-up by live-scaling spare devices (parameters stream at
    the multicast plan's modelled bandwidth while the engine ramps
    ``loaded_layers``);
  * **decode pre-scaling** — a prefill surge forecasts a decode surge one
    generation later, so decode capacity is raised in the same decision;
  * **decode scale-up by mutation** — an active prefill instance flips to
    decode in place (parameters already resident → zero parameter traffic,
    no incast with KVCache migration) while a replacement prefill
    live-scales on a spare device;
  * scale-down by draining: the instance finishes in-flight work, takes
    nothing new, and frees its device.

Every forward pass is a real jitted model execution; time is supplied by
the caller (wall clock in ``launch/serve.py``, virtual clock in tests).
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Iterable

import numpy as np

from repro.core import multicast as mc
from repro.core import topology as topo_mod
from repro.core.autoscaler import Autoscaler, LoadSample, PolicyConfig
from repro.core.live_scaling import LiveSession
from repro.core.parameter_pool import ParameterPool
from repro.net import FAILURE_KINDS, FlowSim, MulticastExecution, NetEvent
from repro.obs.metrics import StatBlock
from repro.obs.trace import NULL_TRACER, NetEventBridge
from repro.serving.disagg import pools as P
from repro.serving.disagg.kv_migration import KVMigrationChannel, make_payload
from repro.serving.engine import InstanceEngine, ServeRequest
from repro.serving.router import Router


@dataclasses.dataclass
class RuntimeStats(StatBlock):
    migrations: int = 0
    migrated_bytes: int = 0
    mutations: int = 0
    mutation_param_bytes: int = 0  # stays 0 — that's the point of §5.4
    live_scaled_prefill: int = 0
    direct_decode_scales: int = 0  # fallback path (incast-prone)
    live_scale_param_bytes: int = 0
    prescaled_decodes: int = 0
    scale_downs: int = 0
    retired: int = 0
    cold_starts: int = 0
    cold_starts_from_host: int = 0  # re-multicast seeded by the O(1) host copy
    preemptions: int = 0  # engines drained by fleet arbitration, not own policy
    rejected: int = 0  # requests shed by fleet admission control
    aborted_param_streams: int = 0  # live-scales killed by a link/NIC failure
    remigrations: int = 0  # KV migrations re-targeted after a failure
    re_prefills: int = 0  # requests re-prefilled after their source died
    cancelled_scales: int = 0  # doomed live-scales torn down by a failure
    #   subscription (fleet's or the runtime's own — immediate, instead of
    #   the drain/retire path)
    failure_replans: int = 0  # engines re-provisioned by the runtime's OWN
    #   failure subscription, inside the failure event


class ClusterRuntime:
    def __init__(
        self,
        cfg,
        params,
        *,
        topo: topo_mod.Topology | None = None,
        policy: PolicyConfig | None = None,
        n_prefill: int = 1,
        n_decode: int = 1,
        n_slots: int = 4,
        max_seq: int = 64,
        prefill_capacity_tps: float = 1000.0,
        decode_capacity_tps: float = 100.0,
        model_bytes: int | None = None,
        page_tokens: int = 16,
        prefills_per_engine_per_tick: int = 1,
        param_pool: ParameterPool | None = None,
        allowed_devices: Iterable[int] | None = None,
        net: FlowSim | None = None,
        failure_subscription: bool = True,
        tracer=None,
        bridge=None,
        metrics=None,
        ledger=None,
        verbose: bool = False,
    ):
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.page_tokens = page_tokens
        self.prefills_per_tick = prefills_per_engine_per_tick
        self.verbose = verbose

        if topo is None:
            topo = topo_mod.add_host_sources(topo_mod.make_cluster(2, 4, bw_gbps=100.0))
        self.topo = topo
        # model_bytes drives the *network model* (live-scale + migration
        # sizing); callers may pass the full-architecture footprint while
        # computing on a reduced config.
        self.model_bytes = model_bytes or cfg.approx_params() * 2
        # a shared pool + an allowed-device set are how the MaaS fleet
        # scheduler multi-tenants several runtimes onto one topology; a
        # standalone runtime owns the whole cluster (allowed_devices=None)
        self.param_pool = param_pool if param_pool is not None else ParameterPool(topo)
        self.allowed_devices = (
            set(allowed_devices) if allowed_devices is not None else None
        )
        self.param_pool.register(cfg.name, self.model_bytes)

        # ONE flow-level network simulator carries every transfer this
        # runtime makes (KV migrations AND live-scaling parameter streams);
        # under MaaS the fleet passes its shared instance so co-tenant
        # traffic contends too
        self.net = net if net is not None else FlowSim(topo)
        # first-class failure subscription (mirrors the MaaS FleetScheduler):
        # a link/device/leaf failure retires doomed LOADING engines and
        # re-plans INSIDE the FlowSim event, not a tick later through the
        # per-flow abort -> drain path.  The fleet passes False for its
        # tenant runtimes — it subscribes once itself and drives the same
        # teardown via fail_devices()/restart_scale(), so a runtime-level
        # subscription would double-handle every failure.
        self._failure_subscribed = failure_subscription
        self._aborted_scales: set[int] = set()  # devs whose param stream
        #   aborted, awaiting the failure event that always follows
        if failure_subscription:
            self.net.subscribe(self._on_net_event)
        # observability: the null tracer keeps every site a no-op; a bound
        # metrics registry mirrors RuntimeStats under runtime.<model>.*
        self.tracer = tracer if tracer is not None else NULL_TRACER
        # flow->span bridge: a standalone traced runtime subscribes its own;
        # under MaaS the fleet passes ONE shared bridge (the FlowSim is
        # shared, so per-runtime bridges would emit duplicate flow spans)
        self.bridge = bridge
        if self.bridge is None and self.tracer.enabled:
            self.bridge = NetEventBridge(self.tracer)
            self.net.subscribe(self.bridge)
        self.metrics = metrics
        # device-time ledger (repro.obs.ledger.DeviceTimeLedger): every tick
        # attributes the elapsed interval to exclusive engine states, owner-
        # keyed by model name so a multi-tenant fleet can split the bill
        self.ledger = ledger
        self._last_ledger_t: float | None = None
        self._scale_spans: dict[int, object] = {}  # loading dev -> open span
        self.pool = P.EnginePool(topo)
        self.channel = KVMigrationChannel(net=self.net, tracer=self.tracer)
        self.router = Router()
        self._live_execs: dict[int, MulticastExecution] = {}  # target dev -> exec
        self._orphan_migrations: list = []  # failed KV payloads awaiting re-target
        self.autoscaler = Autoscaler(
            policy or PolicyConfig(),
            prefill_capacity_tps=prefill_capacity_tps,
            decode_capacity_tps=decode_capacity_tps,
        )
        self.stats = RuntimeStats()
        if metrics is not None:
            self.stats.bind(metrics, f"runtime.{cfg.name}")
        # frozen: policy-driven scaling suspended.  Set while the fleet
        # drains this runtime to zero (a parked model must not re-grow from
        # decaying monitor samples) and by the static-allocation baseline;
        # cold_start() unfreezes.  Monitors keep recording so slo_pressure()
        # stays live for fleet arbitration.
        self.frozen = False
        self._sreqs: dict[int, ServeRequest] = {}
        self.completed: dict[int, ServeRequest] = {}
        self.rejected: dict[int, ServeRequest | None] = {}  # admission-shed
        self._arrived_tokens = 0  # offered prefill load since last monitor tick
        self._decoded_tokens = 0
        self._last_mon: float | None = None

        spare_ids = self._spare_ids()
        if n_prefill + n_decode > len(spare_ids):
            raise ValueError(
                f"requested {n_prefill} prefill + {n_decode} decode instances "
                f"but only {len(spare_ids)} spare devices are available"
            )
        spares = iter(spare_ids)
        for phase, n in ((P.PREFILL, n_prefill), (P.DECODE, n_decode)):
            for _ in range(n):
                dev = next(spares)
                self.pool.add(P.PooledEngine(self._new_engine(), dev, phase))
                self.param_pool.deploy(cfg.name, [dev])

    def _new_engine(self) -> InstanceEngine:
        return InstanceEngine(
            self.cfg, self.params, n_slots=self.n_slots, max_seq=self.max_seq
        )

    def _log(self, msg: str) -> None:
        if self.verbose:
            print(msg)

    # -- multi-tenancy hooks (MaaS fleet arbitration) ------------------------
    def _spare_ids(self) -> list[int]:
        """Free accelerators this runtime may provision — the whole cluster's
        spares for a standalone runtime, only the fleet scheduler's grants
        when multi-tenanted.  Devices with a failed NIC are unusable."""
        ids = [d.id for d in self.topo.spares() if self.net.device_ok(d.id)]
        if self.allowed_devices is not None:
            ids = [i for i in ids if i in self.allowed_devices]
        return ids

    @property
    def n_engines(self) -> int:
        return len(self.pool.all())

    @property
    def n_serving(self) -> int:
        return len(self.pool.serving(P.PREFILL)) + len(self.pool.serving(P.DECODE))

    def slo_pressure(self) -> float:
        """The fleet-arbitration signal: >1 means under-provisioned now."""
        return self.autoscaler.slo_pressure(
            self.pool.n_provisioned(P.PREFILL), self.pool.n_provisioned(P.DECODE)
        )

    def acquire_devices(self, ids: Iterable[int]) -> None:
        """Fleet grant: these devices may be provisioned by this runtime."""
        if self.allowed_devices is None:
            self.allowed_devices = set()
        self.allowed_devices.update(ids)

    def release_devices(self) -> list[int]:
        """Return granted-but-unoccupied devices to the fleet (called every
        scheduler tick — grants not consumed by a scale-up flow back)."""
        if self.allowed_devices is None:
            return []
        freed = [
            i
            for i in sorted(self.allowed_devices)
            if self.topo.device(i).role is topo_mod.Role.FREE
        ]
        self.allowed_devices.difference_update(freed)
        return freed

    def revoke_devices(self, ids: Iterable[int]) -> list[int]:
        """Strip granted devices (dead NICs the fleet's failure subscription
        found) from the allowed set — a doomed grant must not be consumed.
        Returns the devices actually revoked."""
        if self.allowed_devices is None:
            return []
        revoked = [i for i in ids if i in self.allowed_devices]
        self.allowed_devices.difference_update(revoked)
        return revoked

    def _on_net_event(self, event: NetEvent) -> None:
        if event.kind in FAILURE_KINDS:
            self._handle_net_failure(event.t)

    def _handle_net_failure(self, now: float) -> None:
        """React to a link/device/leaf failure the moment the FlowSim emits
        it (standalone-runtime counterpart of the FleetScheduler's
        subscription): retire doomed LOADING engines — those on dead
        devices AND those whose parameter stream aborted without the device
        dying (a severed spine path) — and re-plan each lost phase from
        surviving sources, all inside the same event.  The per-flow abort
        callback only *records* its device (aborts settle before the
        failure event fires), so nothing is drained twice."""
        doomed = self.net.dead_devices() | self._aborted_scales
        self._aborted_scales.clear()
        if not doomed:
            return
        lost = self.fail_devices(doomed, now)
        if self.frozen:
            return  # a parked/drained model must not re-provision itself
        for phase in lost:
            if self.restart_scale(phase, now) is not None:
                self.stats.failure_replans += 1
                self._log(f"[scale] failure re-plan -> {phase} live-scale")

    def fail_devices(self, dead: set[int], now: float) -> list[str]:
        """Failure-subscription teardown (fleet's or the runtime's own):
        tear down live-scales doomed by ``dead`` devices RIGHT NOW — the
        engine is removed from the pool and its device reclaimed
        immediately, instead of waiting for the drain→retire path a tick
        later — and report the phases that lost an engine so the caller can
        re-provision elsewhere.  Idempotent: an engine already torn down is
        gone from the pool, so a second failure event for the same devices
        finds nothing."""
        lost: list[str] = []
        for pe in list(self.pool.all()):
            if pe.device_id not in dead or pe.session is None:
                continue  # only in-flight live-scales are "doomed grants"
            exec_ = self._live_execs.pop(pe.device_id, None)
            if exec_ is not None:
                exec_.cancel(self.net)
            self.pool.engines[pe.phase].remove(pe)
            dev = self.topo.device(pe.device_id)
            dev.role = topo_mod.Role.FREE
            dev.model = None
            self.param_pool.reclaim(self.cfg.name, [pe.device_id])
            self.stats.cancelled_scales += 1
            self._close_scale_span(pe.device_id, now, aborted=True)
            lost.append(pe.phase)
            self._log(
                f"[fleet] cancelled doomed {pe.phase} live-scale on dead "
                f"dev {pe.device_id}"
            )
        return lost

    def restart_scale(
        self, phase: str, now: float, *, target: int | None = None
    ) -> P.PooledEngine | None:
        """Re-provision one engine after a failure — the fleet's re-grant
        path (``target`` pins the affinity-ranked device it just granted)."""
        return self._live_scale(phase, now, target=target)

    def drain_all(self) -> int:
        """Scale-to-zero entry: every engine finishes its in-flight work,
        takes nothing new, and frees its device on retirement.  The shared
        ParameterPool keeps only the single O(1) host copy once the last
        GPU copy is reclaimed."""
        self.frozen = True
        n = 0
        for pe in self.pool.all():
            if pe.state != P.DRAINING:
                self.pool.drain(pe)
                n += 1
        return n

    def cold_start(self, now: float) -> int:
        """Re-provision from zero capacity: live-scale a prefill and a decode
        engine, re-multicasting parameters from a surviving GPU copy if one
        exists, else from the O(1) host-cached copy.  Returns the number of
        engines started."""
        self.frozen = False
        gpu_srcs, _ = self.param_pool.sources(self.cfg.name)
        from_host = not any(self.net.device_ok(s) for s in gpu_srcs)
        n = 0
        for phase in (P.PREFILL, P.DECODE):
            if self._live_scale(phase, now) is not None:
                n += 1
        if n:
            self.stats.cold_starts += 1
            if from_host:
                self.stats.cold_starts_from_host += 1
        return n

    def preempt_one(self, now: float) -> int | None:
        """Fleet-driven preemption: drain the least-loaded engine of the
        better-provisioned phase so a starved co-tenant can take the device
        once it retires.  Returns the device id, or None if nothing can be
        spared without killing a lone phase."""
        cands = {
            ph: [pe for pe in self.pool.phase(ph) if pe.state == P.ACTIVE]
            for ph in (P.PREFILL, P.DECODE)
        }
        phase = max(cands, key=lambda ph: len(cands[ph]))
        if len(cands[phase]) <= 1:
            return None
        victim = min(cands[phase], key=P.PooledEngine.load)
        self.pool.drain(victim)
        self.stats.preemptions += 1
        self._log(f"[fleet] preempted {phase} dev {victim.device_id}")
        return victim.device_id

    def shed_queued(self, n: int, now: float) -> list[int]:
        """Fleet admission control: reject the ``n`` NEWEST queued requests
        (the oldest keep their place — they have aged the most against the
        TTFT SLO).  Rejected requests get an explicit rejection status on
        the router and stop counting as outstanding.  Returns shed rids."""
        shed: list[int] = []
        while n > 0 and self.router.queue:
            rec = self.router.queue.pop()
            self.router.reject(rec.rid, now)
            self.rejected[rec.rid] = self._sreqs.pop(rec.rid, None)
            self.stats.rejected += 1
            shed.append(rec.rid)
            n -= 1
        if shed:
            self._log(f"[fleet] admission control shed {len(shed)} request(s)")
        return shed

    # -- request intake -----------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int, now: float) -> int:
        rid = self.router.submit(len(prompt), max_new_tokens, now)
        self._sreqs[rid] = ServeRequest(rid, np.asarray(prompt, np.int32), max_new_tokens)
        self._arrived_tokens += len(prompt)
        return rid

    @property
    def n_outstanding(self) -> int:
        return len(self._sreqs) - len(self.completed)

    # -- scaling actions ----------------------------------------------------
    def _close_scale_span(self, dev: int, t: float, *,
                          aborted: bool = False) -> None:
        sp = self._scale_spans.pop(dev, None)
        if sp is None:
            return
        if aborted:
            self.tracer.end(sp, t, aborted=True)
        else:
            self.tracer.instant("serving", t, cat="scale", parent=sp)
            self.tracer.end(sp, t)

    def _live_scale(
        self, phase: str, now: float, *, target: int | None = None
    ) -> P.PooledEngine | None:
        """Provision a spare device with a live-scaling engine: the multicast
        plan's hops become real flows on the shared FlowSim, and the engine
        ramps ``loaded_layers`` from the *realized* bytes delivered — so KV
        migrations, co-tenant traffic and degraded links all slow the ramp.
        ``target`` pins a specific spare (the fleet's affinity-ranked
        failure re-grant); otherwise the first spare is taken."""
        spares = self._spare_ids()
        if not spares:
            return None
        target = target if target in spares else spares[0]
        gpu_srcs, host = self.param_pool.sources(self.cfg.name)
        # a copy behind a failed NIC cannot source a multicast: never plan
        # from it (the plan's flows would abort on arrival)
        gpu_srcs = [s for s in gpu_srcs if self.net.device_ok(s)]
        host_devs = [
            d.id for d in self.topo.devices
            if d.is_host and d.host == host and self.net.device_ok(d.id)
        ]
        srcs = gpu_srcs or host_devs
        if not srcs:
            return None
        # the planner sees the same network the data plane simulates: hop
        # latencies (heterogeneous profiles included) rank chains alongside
        # bandwidth, so its transfer_seconds predicts realized arrival
        plan = mc.plan_multicast(
            self.topo, srcs, [target], 1,
            net=self.net, model_bytes=self.model_bytes,
        )
        if target not in plan.covered:
            # degenerate plan (source-only chains / nothing reachable):
            # provisioning an engine on it would ramp from an instant
            # analytic estimate with no bytes ever arriving
            return None
        t_est = max(plan.transfer_seconds(self.model_bytes), 1e-6)
        span = None
        if self.tracer.enabled:
            span = self.tracer.begin(
                "scale_op", now, cat="scale", track="scale",
                phase=phase, device=target, model=self.cfg.name)
            self.tracer.instant("plan", now, cat="scale", parent=span,
                                chains=len(plan.chains))
            self._scale_spans[target] = span
        exec_ = MulticastExecution(
            plan,
            self.model_bytes,
            on_abort=lambda e, t, dev=target: self._param_stream_aborted(dev, t),
            tracer=self.tracer if span is not None else None,
            parent_span=span,
        )
        if self.bridge is not None and span is not None:
            # pin BEFORE start: the chain's hop flows land under this op's
            # scale_op span, which is what the critical-path analyzer
            # partitions the makespan against
            self.bridge.pin_all(exec_.flows, span)
        exec_.start(self.net, now)
        if exec_.aborted:
            # every hop aborted synchronously at start (no live route to the
            # target — e.g. a fully severed uplink that killed no NIC, which
            # device_ok cannot see).  The abort callback fired BEFORE the
            # engine exists, so neither the drain path nor the failure
            # subscription could ever clean it up: don't provision at all.
            self._aborted_scales.discard(target)
            self._close_scale_span(target, now, aborted=True)
            return None
        has_inflow = bool(exec_.flows_into(target))
        session = LiveSession(
            n_layers=self.cfg.n_layers,
            layer_bytes=self.model_bytes // max(self.cfg.n_layers, 1),
            link_bytes_per_s=self.model_bytes / t_est,
            started_at=now,
            progress_bytes=(
                (lambda: exec_.bytes_into(target)) if has_inflow else None
            ),
        )
        eng = self._new_engine()
        eng.set_loaded_layers(0)
        pe = P.PooledEngine(eng, target, phase, state=P.LOADING, session=session)
        self.pool.add(pe)
        # reserve the device; the parameter flows themselves occupy its
        # ingress on the FlowSim (incast with KV migration emerges there)
        self.topo.device(target).role = (
            topo_mod.Role.DECODE if phase == P.DECODE else topo_mod.Role.PREFILL
        )
        self._live_execs[target] = exec_
        self.stats.live_scale_param_bytes += self.model_bytes
        if phase == P.PREFILL:
            self.stats.live_scaled_prefill += 1
        else:
            self.stats.direct_decode_scales += 1
        self._log(
            f"[scale] live-scaling {phase} on dev {target} "
            f"({self.model_bytes/1e6:.0f} MB, ~{t_est*1e3:.0f} ms on dedicated links)"
        )
        return pe

    def _param_stream_aborted(self, dev: int, t: float) -> None:
        """A link/NIC failure killed the parameter stream mid-live-scale.
        When this runtime subscribes to FlowSim failure events, the abort
        only *records* the device — aborts settle before the failure event
        fires, and the subscription handler then retires the doomed engine
        and re-plans inside that event (aborts with no failure event
        attached are swept at the next tick).  Unsubscribed (fleet-managed)
        runtimes keep the legacy behaviour: drain the half-loaded engine so
        it retires next tick and the policy re-plans."""
        self._live_execs.pop(dev, None)
        self.stats.aborted_param_streams += 1
        if self._failure_subscribed:
            self._aborted_scales.add(dev)
            return
        for pe in self.pool.all():
            if pe.device_id == dev and pe.state == P.LOADING:
                self.pool.drain(pe)
                self._log(f"[scale] param stream to dev {dev} aborted -> drain + re-plan")

    def _scale_up_decode(self, now: float) -> bool:
        """§5.4: prefer mutating a prefill instance (zero parameter traffic,
        no incast with KV migration) and live-scale a replacement prefill;
        fall back to a direct decode live-scale only when no prefill can be
        spared.  Returns False when neither path had resources."""
        prefills = self.pool.serving(P.PREFILL)
        can_mutate = prefills and (
            self.pool.n_provisioned(P.PREFILL) >= 2 or self._spare_ids()
        )
        if can_mutate:
            victim = min(prefills, key=P.PooledEngine.load)
            self.pool.mutate_to_decode(victim)
            self.stats.mutations += 1
            self._log(f"[scale] mutated prefill dev {victim.device_id} -> decode (0 param bytes)")
            self._live_scale(P.PREFILL, now)  # replacement; may be None if no spare
            return True
        return self._live_scale(P.DECODE, now) is not None

    def _scale_down(self, phase: str, now: float) -> None:
        cands = self.pool.serving(phase)
        if len(cands) <= 1:
            return
        victim = min(cands, key=P.PooledEngine.load)
        self.pool.drain(victim)
        self.stats.scale_downs += 1
        self._log(f"[scale] draining {phase} dev {victim.device_id}")

    def _accrue_ledger(self, now: float) -> None:
        """Attribute the device-time elapsed since the previous tick to
        exclusive ledger states.  Runs at the top of ``tick()``, BEFORE this
        tick's transitions, so each engine is billed for the state it held
        over the interval: DRAINING -> draining; LOADING with work queued
        against it -> stalled_waiting_layers (the stall live loading exists
        to hide), else loading_params; ACTIVE -> serving_<phase>, or
        allocated_idle when nothing is queued, active, or in flight."""
        last = self._last_ledger_t
        self._last_ledger_t = now
        if last is None:
            return
        dt = now - last
        if dt <= 0:
            return
        led = self.ledger
        owner = self.cfg.name
        waiting = bool(self.router.queue)
        for pe in self.pool.all():
            if pe.state == P.DRAINING:
                state = "draining"
            elif pe.state == P.LOADING:
                state = (
                    "stalled_waiting_layers"
                    if waiting or pe.pending or pe.inflight
                    else "loading_params"
                )
            elif pe.idle():
                state = "allocated_idle"
            else:
                state = (
                    "serving_prefill" if pe.phase == P.PREFILL
                    else "serving_decode"
                )
            led.accrue(state, dt, owner=owner)

    # -- main loop ----------------------------------------------------------
    def tick(self, now: float) -> list[int]:
        """One runtime iteration; returns rids completed this tick."""
        if self.ledger is not None:
            self._accrue_ledger(now)
        # 0. advance the shared network to now (flow completions fire here),
        #    then retire drained instances; free their devices (idle() holds
        #    retirement while KV migrations are still in flight toward one)
        self.net.advance_to(now)
        if self._aborted_scales:
            # param-stream aborts that no failure event followed (a flow
            # started across an already-severed path): same teardown +
            # re-plan as the subscription path, one tick later
            self._handle_net_failure(now)
        for pe in self.pool.retire_idle():
            exec_ = self._live_execs.pop(pe.device_id, None)
            if exec_ is not None:
                # drained mid-live-scale: withdraw the parameter flows so
                # they stop occupying the retired device's ingress
                exec_.cancel(self.net)
            self.param_pool.reclaim(self.cfg.name, [pe.device_id])
            self.stats.retired += 1
            self._close_scale_span(pe.device_id, now, aborted=True)
            self._log(f"[scale] retired {pe.phase} dev {pe.device_id}")

        # 1. advance live-scaling sessions from realized flow progress
        for pe in self.pool.all():
            if pe.state == P.LOADING and pe.session is not None:
                pe.engine.set_loaded_layers(pe.session.layers_loaded(now))
                if pe.engine.can_serve_alone():
                    self.pool.activate(pe)
                    self._live_execs.pop(pe.device_id, None)
                    self._close_scale_span(pe.device_id, now)
                    self.param_pool.deploy(self.cfg.name, [pe.device_id])
                    self._log(f"[scale] dev {pe.device_id} fully loaded -> active {pe.phase}")

        # 2. dispatch prefills (bounded per engine per tick) + start migrations
        budget = {
            id(pe): self.prefills_per_tick for pe in self.pool.serving(P.PREFILL)
        }
        while self.router.queue:
            targets = [
                pe for pe in self.pool.migration_targets()
                if self.net.device_ok(pe.device_id)
            ]
            dst = min(targets, key=P.PooledEngine.load) if targets else None
            src_cands = [
                pe for pe in self.pool.serving(P.PREFILL)
                if budget.get(id(pe), 0) > 0 and self.net.device_ok(pe.device_id)
            ]
            if dst is None or not src_cands:
                break
            src = min(src_cands, key=P.PooledEngine.load)
            budget[id(src)] -= 1
            rec = self.router.queue.popleft()
            sreq = self._sreqs[rec.rid]
            first, one = src.engine.prefill_only(sreq)
            self.router.note_first_token(rec.rid, now)
            payload = make_payload(
                sreq,
                first,
                one,
                max_seq=self.max_seq,
                src_dev=src.device_id,
                dst_dev=dst.device_id,
                page_tokens=self.page_tokens,
            )
            self.router.begin_handoff(
                rec.rid, src.device_id, dst.device_id, len(sreq.out_tokens), now
            )
            self.channel.start(payload, now)
            self.router.mark_migrating(rec.rid)
            dst.inflight += 1
            self.stats.migrations += 1
            self.stats.migrated_bytes += payload.total_bytes

        # 3. migration completions land on their decode instance
        by_dev = {pe.device_id: pe for pe in self.pool.all()}
        for payload in self.channel.poll(now):
            pe = by_dev[payload.dst_dev]
            pe.inflight -= 1
            pe.pending.append(payload)

        # 3.5 failed migrations (link/NIC died mid-flight): the pages are
        # still frozen on the prefill side — re-target onto a surviving
        # decode instance, retrying next tick when none is reachable yet
        for payload in self.channel.take_failed():
            old = by_dev.get(payload.dst_dev)
            if old is not None:
                old.inflight -= 1
            self._orphan_migrations.append(payload)
        if self._orphan_migrations:
            targets = [
                pe for pe in self.pool.migration_targets()
                if self.net.device_ok(pe.device_id)
            ]
            retry, self._orphan_migrations = self._orphan_migrations, []
            for payload in retry:
                if not self.net.device_ok(payload.src_dev):
                    # the SOURCE NIC died: the frozen pages cannot leave that
                    # device — un-pin the request and re-run prefill on a
                    # healthy engine (the re-target path would abort forever)
                    self.router.handoffs.pop(payload.rid, None)
                    payload.request.out_tokens = []
                    self.router.queue.appendleft(self.router.records[payload.rid])
                    self.stats.re_prefills += 1
                    self._log(
                        f"[scale] KV source dev {payload.src_dev} dead -> "
                        f"re-prefilling rid={payload.rid} elsewhere"
                    )
                    continue
                if not targets:
                    self._orphan_migrations.append(payload)
                    continue
                dst = min(targets, key=P.PooledEngine.load)
                payload.dst_dev = dst.device_id
                self.router.begin_handoff(
                    payload.rid, payload.src_dev, dst.device_id,
                    len(payload.tokens_at_freeze), now,
                )
                self.channel.start(payload, now)
                self.router.mark_migrating(payload.rid)
                dst.inflight += 1
                self.stats.remigrations += 1
                self._log(
                    f"[scale] re-targeted failed KV migration rid={payload.rid} "
                    f"-> dev {dst.device_id}"
                )

        # 4. decode: admit migrated requests, then one batched step per engine
        finished_rids: list[int] = []
        for pe in self.pool.phase(P.DECODE):
            eng = pe.engine
            if not eng.can_serve_alone():
                continue
            while pe.pending and eng.free_slots:
                p = pe.pending.popleft()
                eng.admit_prefilled(p.request, p.first_token, p.cache_one)
                # compare against the independent freeze-time snapshot: the
                # request must resume with exactly the tokens it froze with
                # (nothing decoded, lost, or replayed while in transit)
                resumed = (
                    len(p.request.out_tokens)
                    if p.request.out_tokens == p.tokens_at_freeze
                    else -1
                )
                self.router.complete_handoff(p.rid, resumed, now)
            if not eng.active:
                continue
            rids = [r.rid for r in eng.active.values()]
            done = eng.step()
            self._decoded_tokens += len(rids)
            for rid in rids:
                self.router.note_token(rid, now)
            for r in done:
                self.router.note_done(r.rid)
                self.completed[r.rid] = r
                finished_rids.append(r.rid)

        # 5. liveness guard: queued work must never sit against an empty
        #    phase pool — mutation can flip the last prefill instance to
        #    decode after the load monitors already decayed, and decide()
        #    treats zero instances as capacity one, so nothing would ever
        #    re-provision the phase
        if not self.frozen and self.router.queue:
            for phase in (P.PREFILL, P.DECODE):
                if self.pool.n_provisioned(phase) == 0:
                    self._live_scale(phase, now)

        # 6. feed the load monitors + run the scaling policy
        if self._last_mon is None:
            self._last_mon = now
        dt = now - self._last_mon
        if dt > 0:
            decode_kv = max(
                (pe.engine.kv_used_frac() for pe in self.pool.serving(P.DECODE)),
                default=0.0,
            )
            self.autoscaler.prefill_mon.record(
                LoadSample(now, self._arrived_tokens / dt, 0.0, len(self.router.queue))
            )
            self.autoscaler.decode_mon.record(
                LoadSample(now, self._decoded_tokens / dt, decode_kv, 0)
            )
            self._arrived_tokens = 0
            self._decoded_tokens = 0
            self._last_mon = now
            if self.frozen:
                return finished_rids
            decision = self.autoscaler.decide(
                now,
                self.pool.n_provisioned(P.PREFILL),
                self.pool.n_provisioned(P.DECODE),
            )
            for _ in range(max(0, decision.prefill_delta)):
                if self._live_scale(P.PREFILL, now) is None:
                    break
            performed = 0
            for _ in range(max(0, decision.decode_delta)):
                if not self._scale_up_decode(now):
                    break
                performed += 1
            if decision.prescaled and performed:
                # these decode instances were raised by the §5.4 forecast
                # (prefill surge), not by observed decode pressure
                self.stats.prescaled_decodes += performed
            if decision.prefill_delta < 0:
                self._scale_down(P.PREFILL, now)
            if decision.decode_delta < 0:
                self._scale_down(P.DECODE, now)

        return finished_rids

    # -- convenience --------------------------------------------------------
    def run_until_done(self, clock, *, max_ticks: int = 100_000) -> bool:
        """Drive ticks until every submitted request completed.  ``clock``
        is a zero-arg callable returning the current time.  Returns False
        when the tick budget ran out with requests still outstanding."""
        for _ in range(max_ticks):
            if self.n_outstanding == 0:
                return True
            self.tick(clock())
        return self.n_outstanding == 0
