"""Phase-tagged engine pools for PD-disaggregated serving.

A :class:`PooledEngine` wraps one :class:`InstanceEngine` with its cluster
identity: the device it occupies, its phase (prefill or decode), and its
lifecycle state.  The pool supports the two §5.4 transitions that make
decode scaling cheap:

  * **mutation** — a prefill instance becomes a decode instance in place:
    the parameters are already resident, so the transition moves *zero*
    parameter bytes and only flips the device role (egress-busy →
    ingress-busy);
  * **draining** — scale-down marks an instance draining; it finishes its
    in-flight work, accepts nothing new, and frees its device when idle.
"""

from __future__ import annotations

import dataclasses
from collections import deque

from repro.core import topology as topo_mod
from repro.core.live_scaling import LiveSession
from repro.serving.disagg.kv_migration import MigrationPayload
from repro.serving.engine import InstanceEngine

PREFILL = "prefill"
DECODE = "decode"

ACTIVE = "active"
LOADING = "loading"  # live-scaling: parameters still streaming in
DRAINING = "draining"  # scale-down: finish in-flight work, then retire

_PHASE_ROLE = {PREFILL: topo_mod.Role.PREFILL, DECODE: topo_mod.Role.DECODE}


@dataclasses.dataclass
class PooledEngine:
    engine: InstanceEngine
    device_id: int
    phase: str  # PREFILL | DECODE
    state: str = ACTIVE
    session: LiveSession | None = None  # live-scaling progress while LOADING
    pending: deque = dataclasses.field(default_factory=deque)  # migrated payloads awaiting slots
    inflight: int = 0  # KV migrations on the wire toward this engine

    def load(self) -> int:
        """Dispatch-ordering load: queued + active + migrating-in work
        (both landed payloads and flows still on the wire — otherwise every
        migration started within one transfer window piles onto the same
        'least loaded' decode engine)."""
        e = self.engine
        return len(e.queue) + len(e.active) + len(self.pending) + self.inflight

    def idle(self) -> bool:
        return (
            not self.engine.queue
            and not self.engine.active
            and not self.pending
            and self.inflight == 0
        )

    def serving(self) -> bool:
        return self.state == ACTIVE and self.engine.can_serve_alone()


class EnginePool:
    """Both phase pools plus the topology role bookkeeping."""

    def __init__(self, topo: topo_mod.Topology):
        self.topo = topo
        self.engines: dict[str, list[PooledEngine]] = {PREFILL: [], DECODE: []}

    # -- queries ------------------------------------------------------------
    def all(self) -> list[PooledEngine]:
        return self.engines[PREFILL] + self.engines[DECODE]

    def phase(self, phase: str) -> list[PooledEngine]:
        return self.engines[phase]

    def serving(self, phase: str) -> list[PooledEngine]:
        """Engines that may take new work (ACTIVE implies not draining)."""
        return [pe for pe in self.engines[phase] if pe.serving()]

    def migration_targets(self) -> list[PooledEngine]:
        """Decode engines KV pages may be routed to: serving ones, plus
        LOADING ones (a directly live-scaled decode instance receives
        migrations *while* parameters stream in — the §5.4 incast scenario
        the mutation policy exists to avoid; payloads landing on a loading
        engine wait in ``pending`` until it can serve)."""
        return [
            pe
            for pe in self.engines[DECODE]
            if pe.state != DRAINING and (pe.serving() or pe.state == LOADING)
        ]

    def n_provisioned(self, phase: str) -> int:
        """Instances counted against the autoscaler target (incl. loading)."""
        return sum(1 for pe in self.engines[phase] if pe.state != DRAINING)

    # -- lifecycle ----------------------------------------------------------
    def add(self, pe: PooledEngine) -> PooledEngine:
        self.engines[pe.phase].append(pe)
        if pe.state == ACTIVE:
            self.topo.device(pe.device_id).role = _PHASE_ROLE[pe.phase]
        return pe

    def activate(self, pe: PooledEngine) -> None:
        """A LOADING engine finished live-scaling: it now serves alone."""
        pe.state = ACTIVE
        pe.session = None
        self.topo.device(pe.device_id).role = _PHASE_ROLE[pe.phase]

    def mutate_to_decode(self, pe: PooledEngine) -> PooledEngine:
        """§5.4: flip a prefill instance into a decode instance in place.

        Parameters are already resident — zero bytes move; only the device's
        busy link direction changes (prefill egress → decode ingress)."""
        assert pe.phase == PREFILL and pe.state == ACTIVE
        self.engines[PREFILL].remove(pe)
        pe.phase = DECODE
        self.engines[DECODE].append(pe)
        self.topo.device(pe.device_id).role = topo_mod.Role.DECODE
        return pe

    def drain(self, pe: PooledEngine) -> None:
        pe.state = DRAINING

    def retire_idle(self) -> list[PooledEngine]:
        """Remove draining engines that finished their work; free devices.
        ``idle()`` counts in-flight migrations (``inflight``), so an engine
        never retires while KV pages are still on the wire toward it."""
        retired = []
        for phase in (PREFILL, DECODE):
            for pe in list(self.engines[phase]):
                if pe.state == DRAINING and pe.idle():
                    self.engines[phase].remove(pe)
                    dev = self.topo.device(pe.device_id)
                    dev.role = topo_mod.Role.FREE
                    dev.model = None
                    retired.append(pe)
        return retired
