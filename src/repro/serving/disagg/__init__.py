"""PD-disaggregated serving runtime on real JAX engines (paper §2.1, §5.4).

Prefill and decode run on separate engine pools; KV-cache pages migrate
prefill→decode over the topology-modelled compute network; the autoscaler
drives decode pre-scaling and prefill→decode instance *mutation* so decode
scale-ups never incast-collide with live KVCache migration traffic.
"""

from repro.serving.disagg.kv_migration import (
    KVMigrationChannel,
    MigrationPayload,
    payload_bytes,
)
from repro.serving.disagg.pools import EnginePool, PooledEngine
from repro.serving.disagg.runtime import ClusterRuntime, RuntimeStats

__all__ = [
    "ClusterRuntime",
    "EnginePool",
    "KVMigrationChannel",
    "MigrationPayload",
    "PooledEngine",
    "RuntimeStats",
    "payload_bytes",
]
