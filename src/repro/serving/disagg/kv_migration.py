"""KV-cache migration channel: prefill→decode page transfer (paper §2.1, §5.4).

A finished prefill freezes the request's KV pages (the 1-slot cache pytree
the engine produced) and ships them to a decode instance over the scale-out
network.  Transfer time is modelled at the topology's link bandwidth, page-
granular like :class:`repro.models.kvcache.PagedKVCache` blocks.

The channel models the *incast* effect that motivates §5.4's mutation
policy: every flow entering a destination device shares that device's
ingress link.  A decode instance that is simultaneously a live-scaling
target (parameters streaming in) halves every migration headed to it —
which is exactly why BlitzScale mutates an already-parameterised prefill
instance into a decode instance instead of live-scaling decode directly.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np

from repro.core import topology as topo_mod
from repro.serving.engine import ServeRequest

DEFAULT_PAGE_TOKENS = 16  # tokens per migrated KV page (block granularity)


def payload_bytes(cache_one: Any, prompt_len: int, max_seq: int) -> int:
    """Bytes of KV state a request of ``prompt_len`` tokens actually owns.

    The 1-slot cache pytree is allocated at ``max_seq``; only the prompt
    prefix carries information, so the migrated volume is the prompt-length
    fraction of the leaf bytes.  Cache-layout agnostic (GQA / MLA / SSM
    leaves all scale with their seq axis; constant-size SSM state is small
    enough that the approximation is harmless)."""
    total = sum(
        leaf.size * leaf.dtype.itemsize
        for leaf in jax.tree.leaves(cache_one)
        if hasattr(leaf, "dtype")
    )
    return max(1, int(total * prompt_len / max(max_seq, 1)))


@dataclasses.dataclass
class MigrationPayload:
    """One request's frozen KV pages in flight prefill→decode."""

    rid: int
    request: ServeRequest
    first_token: int
    cache_one: Any  # 1-slot cache pytree from InstanceEngine.prefill_only
    prompt_len: int
    total_bytes: int
    n_pages: int
    src_dev: int
    dst_dev: int
    # snapshot of the emitted tokens at freeze time — an independent COPY,
    # so the resume-side gap check can detect the live request being decoded,
    # truncated, or replayed while its KV pages were in flight
    tokens_at_freeze: list[int] = dataclasses.field(default_factory=list)


def make_payload(
    req: ServeRequest,
    first_token: int,
    cache_one: Any,
    *,
    max_seq: int,
    src_dev: int,
    dst_dev: int,
    page_tokens: int = DEFAULT_PAGE_TOKENS,
) -> MigrationPayload:
    prompt_len = int(len(req.prompt))
    nbytes = payload_bytes(cache_one, prompt_len, max_seq)
    n_pages = -(-prompt_len // page_tokens)  # ceil
    return MigrationPayload(
        rid=req.rid,
        request=req,
        first_token=first_token,
        cache_one=cache_one,
        prompt_len=prompt_len,
        total_bytes=nbytes,
        n_pages=n_pages,
        src_dev=src_dev,
        dst_dev=dst_dev,
        tokens_at_freeze=list(req.out_tokens),
    )


@dataclasses.dataclass
class _Flow:
    payload: MigrationPayload
    remaining: float  # bytes left
    last_t: float


class KVMigrationChannel:
    """Models concurrent KV-page flows sharing per-device ingress links.

    ``register_param_stream(dev)`` declares a live-scaling parameter stream
    entering ``dev`` — it competes with migrations for the same ingress
    (incast, §5.4).  ``poll(now)`` integrates progress with fair bandwidth
    sharing and returns payloads that finished arriving."""

    def __init__(self, topo: topo_mod.Topology):
        self.topo = topo
        self.flows: list[_Flow] = []
        self._param_streams: dict[int, int] = {}  # dst device -> n streams

    # -- incast bookkeeping -------------------------------------------------
    def register_param_stream(self, dev: int) -> None:
        self._param_streams[dev] = self._param_streams.get(dev, 0) + 1

    def unregister_param_stream(self, dev: int) -> None:
        n = self._param_streams.get(dev, 0) - 1
        if n <= 0:
            self._param_streams.pop(dev, None)
        else:
            self._param_streams[dev] = n

    def ingress_flows(self, dev: int) -> int:
        """Flows currently sharing ``dev``'s ingress link."""
        mig = sum(1 for f in self.flows if f.payload.dst_dev == dev)
        return mig + self._param_streams.get(dev, 0)

    # -- transfer lifecycle -------------------------------------------------
    def start(self, payload: MigrationPayload, now: float) -> None:
        self.flows.append(_Flow(payload, float(payload.total_bytes), now))

    def poll(self, now: float) -> list[MigrationPayload]:
        """Advance all in-flight transfers to ``now``; return completions."""
        done: list[MigrationPayload] = []
        for f in self.flows:
            dt = max(0.0, now - f.last_t)
            f.last_t = now
            if dt == 0.0 and f.remaining > 0:
                continue
            bw = topo_mod.gbps_to_bytes_per_s(
                self.topo.link_bw(f.payload.src_dev, f.payload.dst_dev)
            )
            share = max(1, self.ingress_flows(f.payload.dst_dev))
            f.remaining -= bw / share * dt
        for f in list(self.flows):
            if f.remaining <= 0:
                self.flows.remove(f)
                done.append(f.payload)
        return done
