"""KV-cache migration channel: prefill→decode page transfer (paper §2.1, §5.4).

A finished prefill freezes the request's KV pages (the 1-slot cache pytree
the engine produced) and ships them to a decode instance over the scale-out
network as a :class:`repro.net.Flow` of kind ``KV_MIGRATION`` — page-
granular like :class:`repro.models.kvcache.PagedKVCache` blocks.

The channel is a thin adapter over the shared flow-level simulator
(:class:`repro.net.FlowSim`); the per-ingress fair-share incast model that
used to live here is deleted.  The *incast* effect that motivates §5.4's
mutation policy now emerges from max-min sharing: a decode instance that is
simultaneously a live-scaling target has the parameter multicast hop and
every migration headed to it contending on the same ingress link — which is
exactly why BlitzScale mutates an already-parameterised prefill instance
into a decode instance instead of live-scaling decode directly.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np

from repro.core import topology as topo_mod
from repro.net import Flow, FlowKind, FlowSim
from repro.serving.engine import ServeRequest

DEFAULT_PAGE_TOKENS = 16  # tokens per migrated KV page (block granularity)


def payload_bytes(cache_one: Any, prompt_len: int, max_seq: int) -> int:
    """Bytes of KV state a request of ``prompt_len`` tokens actually owns.

    The 1-slot cache pytree is allocated at ``max_seq``; only the prompt
    prefix carries information, so the migrated volume is the prompt-length
    fraction of the leaf bytes.  Cache-layout agnostic (GQA / MLA / SSM
    leaves all scale with their seq axis; constant-size SSM state is small
    enough that the approximation is harmless)."""
    total = sum(
        leaf.size * leaf.dtype.itemsize
        for leaf in jax.tree.leaves(cache_one)
        if hasattr(leaf, "dtype")
    )
    return max(1, int(total * prompt_len / max(max_seq, 1)))


@dataclasses.dataclass
class MigrationPayload:
    """One request's frozen KV pages in flight prefill→decode."""

    rid: int
    request: ServeRequest
    first_token: int
    cache_one: Any  # 1-slot cache pytree from InstanceEngine.prefill_only
    prompt_len: int
    total_bytes: int
    n_pages: int
    src_dev: int
    dst_dev: int
    # snapshot of the emitted tokens at freeze time — an independent COPY,
    # so the resume-side gap check can detect the live request being decoded,
    # truncated, or replayed while its KV pages were in flight
    tokens_at_freeze: list[int] = dataclasses.field(default_factory=list)
    # realized transfer timestamps (latency + contention included) — small
    # KV payloads are latency-dominated under the per-hop latency model,
    # and this is where that shows up per request
    sent_at: float | None = None
    landed_at: float | None = None

    @property
    def transfer_seconds(self) -> float | None:
        if self.sent_at is None or self.landed_at is None:
            return None
        return self.landed_at - self.sent_at


def make_payload(
    req: ServeRequest,
    first_token: int,
    cache_one: Any,
    *,
    max_seq: int,
    src_dev: int,
    dst_dev: int,
    page_tokens: int = DEFAULT_PAGE_TOKENS,
) -> MigrationPayload:
    prompt_len = int(len(req.prompt))
    nbytes = payload_bytes(cache_one, prompt_len, max_seq)
    n_pages = -(-prompt_len // page_tokens)  # ceil
    return MigrationPayload(
        rid=req.rid,
        request=req,
        first_token=first_token,
        cache_one=cache_one,
        prompt_len=prompt_len,
        total_bytes=nbytes,
        n_pages=n_pages,
        src_dev=src_dev,
        dst_dev=dst_dev,
        tokens_at_freeze=list(req.out_tokens),
    )


class KVMigrationChannel:
    """KV-page flows on the shared flow-level network simulator.

    ``start`` launches one ``KV_MIGRATION`` flow per frozen request;
    ``poll(now)`` advances the underlying :class:`FlowSim` to ``now`` and
    returns payloads whose flows finished arriving.  Bandwidth sharing —
    including incast with live-scaling parameter streams, multicast chains
    and co-tenant traffic — is entirely the simulator's max-min allocation;
    a standalone channel builds its own FlowSim, a ClusterRuntime passes
    the runtime-wide (or, under MaaS, fleet-wide) one."""

    def __init__(self, topo: topo_mod.Topology | None = None, *,
                 net: FlowSim | None = None, tracer=None):
        if net is None:
            if topo is None:
                raise ValueError("KVMigrationChannel needs a topology or a FlowSim")
            net = FlowSim(topo)
        self.net = net
        # duck-typed (repro.obs.Tracer-shaped); None / disabled -> no spans
        self.tracer = tracer if tracer is not None and tracer.enabled else None
        self._spans: dict[int, object] = {}  # rid -> open migration span
        self._arrived: list[MigrationPayload] = []
        self._failed: list[MigrationPayload] = []
        self.transfer_log: list[float] = []  # realized seconds per landing

    @property
    def flows(self) -> list[Flow]:
        """In-flight KV migration flows (on the shared simulator)."""
        return [f for f in self.net.flows if f.kind is FlowKind.KV_MIGRATION]

    def inflight_to(self, dev: int) -> int:
        # indexed on the simulator (dst table) — no scan over the fleet's
        # whole flow population just to admit one migration
        return len(self.net.flows_into(dev, (FlowKind.KV_MIGRATION,)))

    # -- transfer lifecycle -------------------------------------------------
    def start(self, payload: MigrationPayload, now: float) -> None:
        self.net.advance_to(now)
        payload.sent_at = self.net.now  # before start: an instant (same-
        payload.landed_at = None  # device) landing fires _landed inside it
        if self.tracer is not None:
            self._spans[payload.rid] = self.tracer.begin(
                "kv_migration", self.net.now, cat="migration",
                track="migration", rid=payload.rid, src=payload.src_dev,
                dst=payload.dst_dev, bytes=payload.total_bytes)
        self.net.start(
            Flow(
                FlowKind.KV_MIGRATION,
                payload.src_dev,
                payload.dst_dev,
                float(payload.total_bytes),
                payload=payload,
                on_complete=self._landed,
                on_abort=self._aborted,
                tag=f"kv:{payload.rid}",
            )
        )

    def _landed(self, flow: Flow, t: float) -> None:
        flow.payload.landed_at = t
        self.transfer_log.append(t - flow.payload.sent_at)
        if self.tracer is not None:
            self.tracer.end(self._spans.pop(flow.payload.rid, None), t)
        self._arrived.append(flow.payload)

    def _aborted(self, flow: Flow, t: float) -> None:
        # a link/NIC failure killed the transfer: the frozen pages are
        # still resident on the prefill side, so the caller re-targets
        # (take_failed) instead of losing the request
        if self.tracer is not None:
            self.tracer.end(self._spans.pop(flow.payload.rid, None), t,
                            aborted=True)
        self._failed.append(flow.payload)

    def poll(self, now: float) -> list[MigrationPayload]:
        """Advance the network to ``now``; return payloads that arrived."""
        self.net.advance_to(now)
        done, self._arrived = self._arrived, []
        return done

    def take_failed(self) -> list[MigrationPayload]:
        """Payloads whose flows were aborted by a failure — the runtime
        re-targets them onto a surviving decode instance."""
        out, self._failed = self._failed, []
        return out
