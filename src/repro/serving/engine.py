"""Per-instance serving engine: continuous batching over jitted JAX steps.

One :class:`InstanceEngine` is what runs on a serving instance (a TP group of
chips).  It owns the parameters, a slotted KV cache, and pre-lowered
executables — the TPU analogue of the paper's CUDA-context-pool trick
(App. A.1): the decode step compiles once per (arch, n_slots) and prefill
once per prompt-length bucket, so autoscaling never pays a compile at
scale time.

Continuous batching (Orca-style): a fixed number of decode slots; finished
sequences free their slot immediately and queued requests are admitted at
the next step boundary.  ``loaded_layers`` tracks live-scaling progress: a
partially-loaded engine reports ``can_serve_alone() == False`` and the live
execution scheduler routes its work through cooperative execution instead.
"""

from __future__ import annotations

import dataclasses
import functools
from collections import deque
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as TF
from repro.models.config import ModelConfig


@dataclasses.dataclass
class ServeRequest:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int
    out_tokens: list[int] = dataclasses.field(default_factory=list)
    slot: int | None = None
    done: bool = False


class InstanceEngine:
    """Continuous-batching engine around the unified model."""

    def __init__(
        self,
        cfg: ModelConfig,
        params: dict,
        *,
        n_slots: int = 8,
        max_seq: int = 512,
    ):
        # per-row (non-lockstep) appends: engine slots are admitted at
        # different times, so their cache positions differ (§Perf C2 note)
        self.cfg = cfg = cfg.replace(uniform_decode=False)
        self.params = params
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.queue: deque[ServeRequest] = deque()
        self.active: dict[int, ServeRequest] = {}  # slot -> request
        self.free_slots = list(range(n_slots))[::-1]
        self.caches = TF.init_caches(cfg, n_slots, max_seq)
        self.last_tokens = jnp.zeros((n_slots,), jnp.int32)
        self.slot_live = jnp.zeros((n_slots,), bool)
        self.loaded_layers = cfg.n_layers  # < n_layers while live-scaling
        self.steps = 0

        n = self.n_slots

        @jax.jit
        def _decode_all(params, last_tokens, caches, live_mask):
            nxt, new_caches = TF.decode_step(cfg, params, last_tokens, caches)

            def sel(new, old):
                if new.ndim >= 2 and new.shape[1] == n:
                    shape = (1, n) + (1,) * (new.ndim - 2)
                    return jnp.where(live_mask.reshape(shape), new, old)
                return new

            merged = jax.tree.map(sel, new_caches, caches)
            return jnp.where(live_mask, nxt, last_tokens), merged

        @jax.jit
        def _prefill_one(params, tokens):
            one = TF.init_caches(cfg, 1, max_seq)
            return TF.prefill(cfg, params, tokens, one)

        self._decode_all = _decode_all
        self._prefill_one = _prefill_one

    # -- live scaling hooks -----------------------------------------------------
    def set_loaded_layers(self, k: int) -> None:
        self.loaded_layers = min(k, self.cfg.n_layers)

    def can_serve_alone(self) -> bool:
        return self.loaded_layers >= self.cfg.n_layers

    # -- public API --------------------------------------------------------------
    def submit(self, req: ServeRequest) -> None:
        self.queue.append(req)

    def _splice_slot(self, slot: int, one: Any, first_token: int) -> None:
        """Install a 1-slot prefill cache + its first sampled token into
        ``slot``.  Shared by local admission and disagg KV-migration admission
        so both paths are numerically identical."""

        def splice(old, new):
            if old.ndim >= 2 and old.shape[1] == self.n_slots:
                return old.at[:, slot].set(new[:, 0])
            return old

        self.caches = jax.tree.map(splice, self.caches, one)
        self.last_tokens = self.last_tokens.at[slot].set(int(first_token))
        self.slot_live = self.slot_live.at[slot].set(True)

    def _admit(self) -> None:
        while self.queue and self.free_slots:
            req = self.queue.popleft()
            slot = self.free_slots.pop()
            req.slot = slot
            nxt, one = self.prefill_only(req)
            self._splice_slot(slot, one, nxt)
            self.active[slot] = req

    # -- disaggregated-serving entry points --------------------------------------
    def prefill_only(self, req: ServeRequest) -> tuple[int, Any]:
        """Run the prefill phase only: returns (first_token, 1-slot cache).

        On a prefill instance this is the whole job — the returned cache is
        the KV-migration payload; the first token is emitted here (TTFT is a
        prefill-side metric in PD disaggregation)."""
        tokens = jnp.asarray(req.prompt[None].astype(np.int32))
        nxt, one = self._prefill_one(self.params, tokens)
        first = int(nxt[0])
        req.out_tokens.append(first)
        return first, one

    def admit_prefilled(self, req: ServeRequest, first_token: int, one: Any) -> bool:
        """Admit a request whose prefill ran elsewhere (KV cache migrated in).

        Returns False when no decode slot is free — the caller keeps the
        payload queued.  The splice is the same op local admission uses, so
        decode continues bit-identically from the migrated state."""
        if not self.free_slots:
            return False
        slot = self.free_slots.pop()
        req.slot = slot
        self._splice_slot(slot, one, first_token)
        self.active[slot] = req
        return True

    def kv_used_frac(self) -> float:
        """Fraction of KV capacity held by live sequences (autoscaler signal)."""
        used = sum(
            len(r.prompt) + len(r.out_tokens) for r in self.active.values()
        )
        return used / float(self.n_slots * self.max_seq)

    def step(self) -> list[ServeRequest]:
        """One continuous-batching iteration; returns finished requests."""
        self._admit()
        finished: list[ServeRequest] = []
        if not self.active:
            return finished
        nxt, self.caches = self._decode_all(
            self.params, self.last_tokens, self.caches, self.slot_live
        )
        self.last_tokens = nxt
        self.steps += 1
        for slot, req in list(self.active.items()):
            req.out_tokens.append(int(nxt[slot]))
            if len(req.out_tokens) >= req.max_new_tokens:
                req.done = True
                finished.append(req)
                self.active.pop(slot)
                self.free_slots.append(slot)
                self.slot_live = self.slot_live.at[slot].set(False)
        return finished

    def run_until_done(self, max_steps: int = 10_000) -> list[ServeRequest]:
        out: list[ServeRequest] = []
        for _ in range(max_steps):
            out.extend(self.step())
            if not self.active and not self.queue:
                break
        return out
