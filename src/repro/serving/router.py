"""Cluster-level request router: FCFS dispatch + SLO accounting.

The router is the component between the load balancer and the per-instance
engines (Fig. 6): it keeps one FCFS queue per model, dispatches to the
least-loaded *fully-loaded* instance, and — during live scaling — routes
through the cooperative (source, target) pair per the three-step transition
protocol (§5.2): a partially-loaded engine never receives requests directly;
its work arrives via the paired source's shared priority queue.

SLO accounting matches the paper's §6.2 definition: a request violates when
TTFT or any TBT exceeds 5x the workload's average.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any

import numpy as np


@dataclasses.dataclass
class RequestRecord:
    rid: int
    arrival: float
    prompt_tokens: int
    max_new_tokens: int
    ttft: float | None = None
    token_times: list = dataclasses.field(default_factory=list)
    done: bool = False

    def tbts(self) -> list[float]:
        ts = self.token_times
        return [b - a for a, b in zip(ts, ts[1:])]


@dataclasses.dataclass
class SLOReport:
    n: int
    mean_ttft: float
    p99_ttft: float
    mean_tbt: float
    p99_tbt: float
    attainment: float  # fraction within 5x-average SLO (paper §6.2)


class Router:
    """FCFS router over a set of engines (objects with ``submit``/``step``
    and ``can_serve_alone``)."""

    def __init__(self):
        self.queue: deque[RequestRecord] = deque()
        self.records: dict[int, RequestRecord] = {}
        self._rid = 0

    def submit(self, prompt_tokens: int, max_new_tokens: int, now: float) -> int:
        self._rid += 1
        rec = RequestRecord(self._rid, now, prompt_tokens, max_new_tokens)
        self.records[rec.rid] = rec
        self.queue.append(rec)
        return rec.rid

    def dispatch(self, engines: list[Any]) -> list[tuple[RequestRecord, Any]]:
        """Assign queued requests FCFS to the least-loaded serving-capable
        engine.  Engines mid-live-scaling (can_serve_alone() False) are
        skipped — their work arrives via cooperative execution."""
        ready = [e for e in engines if getattr(e, "can_serve_alone", lambda: True)()]
        out = []
        while self.queue and ready:
            eng = min(ready, key=lambda e: len(getattr(e, "queue", [])) + len(getattr(e, "active", {})))
            rec = self.queue.popleft()
            out.append((rec, eng))
        return out

    # -- SLO accounting ------------------------------------------------------
    def note_first_token(self, rid: int, now: float) -> None:
        rec = self.records[rid]
        if rec.ttft is None:
            rec.ttft = now - rec.arrival
        rec.token_times.append(now)

    def note_token(self, rid: int, now: float) -> None:
        self.records[rid].token_times.append(now)

    def note_done(self, rid: int) -> None:
        self.records[rid].done = True

    def slo_report(self, multiplier: float = 5.0) -> SLOReport:
        recs = [r for r in self.records.values() if r.ttft is not None]
        if not recs:
            return SLOReport(0, 0, 0, 0, 0, 1.0)
        ttfts = np.array([r.ttft for r in recs])
        tbts = np.concatenate([np.array(r.tbts()) for r in recs if r.tbts()] or [np.zeros(1)])
        t_slo = multiplier * float(ttfts.mean())
        b_slo = multiplier * float(tbts.mean()) if len(tbts) else float("inf")
        ok = sum(
            1
            for r in recs
            if r.ttft <= t_slo and all(t <= b_slo for t in r.tbts())
        )
        return SLOReport(
            n=len(recs),
            mean_ttft=float(ttfts.mean()),
            p99_ttft=float(np.percentile(ttfts, 99)),
            mean_tbt=float(tbts.mean()),
            p99_tbt=float(np.percentile(tbts, 99)),
            attainment=ok / len(recs),
        )
