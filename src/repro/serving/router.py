"""Cluster-level request router: FCFS dispatch + SLO accounting.

The router is the component between the load balancer and the per-instance
engines (Fig. 6): it keeps one FCFS queue per model, dispatches to the
least-loaded *fully-loaded* instance, and — during live scaling — routes
through the cooperative (source, target) pair per the three-step transition
protocol (§5.2): a partially-loaded engine never receives requests directly;
its work arrives via the paired source's shared priority queue.

SLO accounting matches the paper's §6.2 definition: a request violates when
TTFT or any TBT exceeds 5x the workload's average.

For PD-disaggregated serving the router additionally owns the three-step
transition handoff of a migrating request (mirroring the live-scaling
protocol of §5.2, applied to prefill→decode KV migration):

  1. PREFILLED — the prefill instance emitted the first token and froze the
                 request's KV pages; the router pins the request (no engine
                 may decode it);
  2. MIGRATING — pages are in flight on the compute network; the first
                 token is already accounted, so nothing is dropped while
                 the request is in transit;
  3. RESUMED   — the decode instance spliced the pages and continues from
                 the exact migrated position.

``complete_handoff`` verifies the resume position equals the freeze
position — a migrating request must never drop or duplicate tokens.
"""

from __future__ import annotations

import dataclasses
import enum
import time
from collections import deque
from typing import Any

import numpy as np


class HandoffPhase(enum.Enum):
    PREFILLED = "prefilled"
    MIGRATING = "migrating"
    RESUMED = "resumed"


@dataclasses.dataclass
class Handoff:
    rid: int
    src: int  # prefill instance/device id
    dst: int  # decode instance/device id
    tokens_frozen: int  # tokens emitted when the KV pages were frozen
    phase: HandoffPhase = HandoffPhase.PREFILLED
    t_begin: float = 0.0
    t_resume: float | None = None


@dataclasses.dataclass
class RequestRecord:
    rid: int
    arrival: float
    prompt_tokens: int
    max_new_tokens: int
    ttft: float | None = None
    token_times: list = dataclasses.field(default_factory=list)
    done: bool = False
    rejected: bool = False  # shed by admission control (never served)
    rejected_at: float | None = None

    def tbts(self) -> list[float]:
        ts = self.token_times
        return [b - a for a, b in zip(ts, ts[1:])]


@dataclasses.dataclass
class SLOReport:
    n: int
    mean_ttft: float
    p99_ttft: float
    mean_tbt: float
    p99_tbt: float
    attainment: float  # fraction within 5x-average SLO (paper §6.2)


class Router:
    """FCFS router over a set of engines (objects with ``submit``/``step``
    and ``can_serve_alone``)."""

    def __init__(self):
        self.queue: deque[RequestRecord] = deque()
        self.records: dict[int, RequestRecord] = {}
        self.handoffs: dict[int, Handoff] = {}
        self.dropped: list[int] = []  # rids that lost/duplicated tokens in transit
        self.rejections: list[int] = []  # rids shed by admission control
        self._rid = 0

    def submit(self, prompt_tokens: int, max_new_tokens: int, now: float) -> int:
        self._rid += 1
        rec = RequestRecord(self._rid, now, prompt_tokens, max_new_tokens)
        self.records[rec.rid] = rec
        self.queue.append(rec)
        return rec.rid

    def dispatch(self, engines: list[Any]) -> list[tuple[RequestRecord, Any]]:
        """Assign queued requests FCFS to the least-loaded serving-capable
        engine.  Engines mid-live-scaling (can_serve_alone() False) are
        skipped — their work arrives via cooperative execution.  Requests
        pinned by an open handoff (KV pages frozen or in flight) are never
        dispatched."""
        ready = [e for e in engines if getattr(e, "can_serve_alone", lambda: True)()]
        out = []
        deferred = []
        while self.queue and ready:
            rec = self.queue.popleft()
            if self.pinned(rec.rid):
                deferred.append(rec)
                continue
            eng = min(ready, key=lambda e: len(getattr(e, "queue", [])) + len(getattr(e, "active", {})))
            out.append((rec, eng))
        self.queue.extendleft(reversed(deferred))
        return out

    # -- three-step PD handoff ----------------------------------------------
    def begin_handoff(
        self, rid: int, src: int, dst: int, tokens_frozen: int, now: float
    ) -> Handoff:
        """Step 1: freeze the request's KV pages on the prefill instance.
        While a handoff is open (PREFILLED or MIGRATING) the request is
        pinned — ``dispatch`` will never hand it to an engine."""
        h = Handoff(rid, src, dst, tokens_frozen, HandoffPhase.PREFILLED, t_begin=now)
        self.handoffs[rid] = h
        return h

    def mark_migrating(self, rid: int) -> None:
        """Step 2: the frozen pages entered the network toward ``dst``."""
        self.handoffs[rid].phase = HandoffPhase.MIGRATING

    def complete_handoff(self, rid: int, tokens_resumed: int, now: float) -> bool:
        """Step 3: the decode instance spliced the pages and resumes.  Returns
        True when the resume position matches the freeze position (no token
        dropped or replayed); mismatches are recorded in ``dropped``."""
        h = self.handoffs[rid]
        h.phase = HandoffPhase.RESUMED
        h.t_resume = now
        ok = tokens_resumed == h.tokens_frozen
        if not ok:
            self.dropped.append(rid)
        return ok

    def in_transit(self, rid: int) -> bool:
        h = self.handoffs.get(rid)
        return h is not None and h.phase is HandoffPhase.MIGRATING

    def pinned(self, rid: int) -> bool:
        """True while a handoff is open (not yet RESUMED)."""
        h = self.handoffs.get(rid)
        return h is not None and h.phase is not HandoffPhase.RESUMED

    def handoff_report(self) -> tuple[int, int]:
        """(completed handoffs, token-gapped requests)."""
        done = sum(1 for h in self.handoffs.values() if h.phase is HandoffPhase.RESUMED)
        return done, len(self.dropped)

    # -- SLO accounting ------------------------------------------------------
    def note_first_token(self, rid: int, now: float) -> None:
        rec = self.records[rid]
        if rec.ttft is None:
            rec.ttft = now - rec.arrival
        rec.token_times.append(now)

    def note_token(self, rid: int, now: float) -> None:
        self.records[rid].token_times.append(now)

    def note_done(self, rid: int) -> None:
        self.records[rid].done = True

    def reject(self, rid: int, now: float) -> None:
        """Admission control: mark a never-dispatched request as explicitly
        rejected (the caller removes it from the queue).  Rejected requests
        are excluded from SLO accounting — they were refused, not violated."""
        rec = self.records[rid]
        rec.rejected = True
        rec.rejected_at = now
        self.rejections.append(rid)

    def slo_report(self, multiplier: float = 5.0) -> SLOReport:
        recs = [r for r in self.records.values() if r.ttft is not None]
        if not recs:
            return SLOReport(0, 0, 0, 0, 0, 1.0)
        ttfts = np.array([r.ttft for r in recs])
        tbts = np.concatenate([np.array(r.tbts()) for r in recs if r.tbts()] or [np.zeros(1)])
        t_slo = multiplier * float(ttfts.mean())
        b_slo = multiplier * float(tbts.mean()) if len(tbts) else float("inf")
        ok = sum(
            1
            for r in recs
            if r.ttft <= t_slo and all(t <= b_slo for t in r.tbts())
        )
        return SLOReport(
            n=len(recs),
            mean_ttft=float(ttfts.mean()),
            p99_ttft=float(np.percentile(ttfts, 99)),
            mean_tbt=float(tbts.mean()),
            p99_tbt=float(np.percentile(tbts, 99)),
            attainment=ok / len(recs),
        )
