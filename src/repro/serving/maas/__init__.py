"""Serverless multi-model MaaS control plane (paper §1, §5.3).

N models share one GPU fleet: a :class:`FleetScheduler` arbitrates free
devices between per-model :class:`~repro.serving.disagg.runtime.ClusterRuntime`s
(priority = SLO pressure × queue depth), parks idle models at *zero*
accelerators — only the single O(1) host copy in the shared
:class:`~repro.core.parameter_pool.ParameterPool` survives — and cold-starts
them back in seconds by re-multicasting from that copy (or any surviving
GPU copy).  Starved hot models preempt idle ones.
"""

from repro.serving.maas.fleet import FleetPolicy, FleetScheduler, FleetStats
from repro.serving.maas.tenant import (
    ACTIVE,
    DRAINING,
    LATENCY,
    THROUGHPUT,
    ZERO,
    Tenant,
    TenantStats,
)

__all__ = [
    "ACTIVE",
    "DRAINING",
    "FleetPolicy",
    "FleetScheduler",
    "FleetStats",
    "LATENCY",
    "THROUGHPUT",
    "Tenant",
    "TenantStats",
    "ZERO",
]
