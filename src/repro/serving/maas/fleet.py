"""Fleet-wide GPU arbitration for serverless multi-model MaaS (paper §1, §5.3).

The paper's premise is that many models share one GPU fleet: each scales up
in seconds via GPU-to-GPU multicast, and *down to zero accelerators* — only
the single O(1) host-DRAM copy in the shared :class:`ParameterPool` remains
— so the fleet's free devices are a common pool every model draws from.
This module is the control plane that makes those decisions:

  * **arbitration** — each tick, free devices are granted to per-model
    runtimes in priority order (priority = SLO pressure × queue depth);
    grants a runtime does not consume flow back the next tick, so devices
    move between models at tick granularity;
  * **scale-to-zero** — a model idle past a timeout drains all engines and
    releases every device; the ParameterPool keeps exactly one host copy;
  * **cold start** — a request for a parked model triggers a re-multicast
    live-scale from a surviving GPU copy (possibly a draining co-instance)
    or, when none exists, the O(1) host copy;
  * **preemption** — when a hot model is starved (pressure above bound, no
    free device), the lowest-priority idle model is drained to give up
    devices.

The per-model scaling *mechanism* stays inside each
:class:`~repro.serving.disagg.runtime.ClusterRuntime` (live-scaling,
mutation, decode pre-scaling, §5.4); the fleet only decides who may hold
which accelerator.
"""

from __future__ import annotations

import dataclasses
import math

from repro.core import topology as topo_mod
from repro.core.parameter_pool import ParameterPool
from repro.net import FAILURE_KINDS, FlowSim, NetEvent
from repro.obs.metrics import MetricRegistry, StatBlock
from repro.obs.trace import NULL_TRACER, NetEventBridge
from repro.serving.disagg import pools as P
from repro.serving.disagg.runtime import ClusterRuntime
from repro.serving.maas import tenant as T
from repro.serving.maas.tenant import Tenant


@dataclasses.dataclass
class FleetPolicy:
    idle_to_zero_s: float = 3.0  # drain a model idle this long (scale-to-zero)
    grow_pressure: float = 1.0  # grant devices above this SLO pressure
    starve_pressure: float = 1.0  # an unserved demander above this may preempt
    preempt_pressure: float = 0.5  # victims must be *below* this priority
    max_grant_per_tick: int = 2  # per-tenant grant rate limit
    arbitration: bool = True  # False = static allocation (benchmark baseline)
    # SLO-burn tie-break: at equal arbitration pressure, a tenant whose SLO
    # monitor says ``page`` outranks one at ``warn`` outranks ``ok`` — the
    # fleet_health() surface feeding back into the grant loop.  No-op when
    # no SLOMonitor is attached.
    slo_aware_arbitration: bool = True
    scale_to_zero: bool = True
    # admission control: when the fleet saturates (no grantable device and
    # every demander above saturation_pressure), queued requests of the
    # LOWEST SLO class present are shed beyond this depth instead of letting
    # queues grow unboundedly
    admission_control: bool = True
    saturation_pressure: float = 1.0
    shed_queue_depth: int = 64
    # placement affinity: FlowSim transfer-time estimates are computed for
    # at most this many affinity-ranked candidates per grant decision
    affinity_estimates: int = 8


@dataclasses.dataclass
class FleetStats(StatBlock):
    cold_starts: int = 0
    scale_to_zero_events: int = 0
    preemptions: int = 0
    grants: int = 0  # devices handed out by arbitration
    rejections: int = 0  # requests shed by admission control
    gpu_seconds: float = 0.0  # fleet-wide device-seconds occupied by engines
    grant_cancellations: int = 0  # granted devices revoked on NIC/leaf death
    failure_regrants: int = 0  # engines re-granted by the failure subscription


class FleetScheduler:
    """N models on one shared topology + one shared O(1) parameter pool."""

    def __init__(
        self,
        topo: topo_mod.Topology,
        *,
        policy: FleetPolicy | None = None,
        net: FlowSim | None = None,
        tracer=None,
        metrics: MetricRegistry | None = None,
        ledger=None,
        slo_monitor=None,
        flight_recorder=None,
        verbose: bool = False,
    ):
        self.topo = topo
        self.policy = policy or FleetPolicy()
        self.param_pool = ParameterPool(topo)
        # ONE flow-level network simulator for the whole fleet: every
        # tenant's KV migrations, live-scale parameter streams and cold
        # starts contend on the same links (and its transfer-time estimates
        # drive placement affinity)
        self.net = net if net is not None else FlowSim(topo)
        self.tenants: dict[str, Tenant] = {}
        # ONE registry for the whole fleet: FleetStats plus every tenant's
        # RuntimeStats/TenantStats mirror into it under fleet./runtime.<m>./
        # tenant.<m>. prefixes — one queryable, JSON-able surface
        self.tracer = tracer if tracer is not None else NULL_TRACER
        # ONE flow->span bridge for the whole fleet (the FlowSim is shared:
        # per-runtime bridges would emit duplicate spans per flow); tenant
        # runtimes receive it so _live_scale can pin its parameter flows
        # under the scale_op span
        self.bridge = None
        if self.tracer.enabled:
            self.bridge = NetEventBridge(self.tracer)
            self.net.subscribe(self.bridge)
        self.metrics = metrics if metrics is not None else MetricRegistry()
        self.stats = FleetStats().bind(self.metrics, "fleet")
        # fleet-wide device-time ledger: tenant runtimes accrue their own
        # engine states into it (owner = model name); the fleet adds only
        # the granted-but-unconsumed FREE devices, so nothing double-bills
        self.ledger = ledger
        # streaming SLO monitor: fed per-tenant from completed requests each
        # tick; fleet_health() is its observe-only summary surface
        self.slo_monitor = slo_monitor
        # anomaly-triggered flight recorder: rides the same FlowSim
        # subscription for failure triggers; SLO-page escalations are
        # edge-detected by poll() at the end of every tick
        self.flight_recorder = flight_recorder
        if flight_recorder is not None:
            flight_recorder.attach(self.net)
        self.verbose = verbose
        self._last_tick: float | None = None
        # first-class failure subscription: the scheduler learns of a
        # leaf/device death the instant the FlowSim processes it — not one
        # tick later via the victim runtime's drain path — and immediately
        # cancels doomed grants and re-grants on surviving leaves
        self.net.subscribe(self._on_net_event)

    def _log(self, msg: str) -> None:
        if self.verbose:
            print(msg)

    # -- fleet membership ----------------------------------------------------
    def free_devices(self) -> list[int]:
        """Spare accelerators owned by no tenant — the arbitration pool.
        Devices with a failed NIC are not grantable."""
        owned: set[int] = set()
        for t in self.tenants.values():
            if t.runtime.allowed_devices:
                owned |= t.runtime.allowed_devices
        return [
            d.id
            for d in self.topo.spares()
            if d.id not in owned and self.net.device_ok(d.id)
        ]

    def add_model(
        self,
        cfg,
        params,
        *,
        n_prefill: int = 1,
        n_decode: int = 1,
        slo_class: str = T.LATENCY,
        **runtime_kw,
    ) -> Tenant:
        """Register a model with the fleet and seat it on free devices.

        The runtime shares the fleet's topology, ParameterPool and FlowSim;
        its allowed-device set starts as exactly the initial grant, so it
        can never provision outside what arbitration hands it.

        ``slo_class`` is the tenant's SLO tier (``tenant.LATENCY`` or
        ``tenant.THROUGHPUT``): it weights arbitration priority and decides
        who is shed first under admission control."""
        if cfg.name in self.tenants:
            raise ValueError(f"model {cfg.name!r} already registered")
        free = self.free_devices()
        need = n_prefill + n_decode
        if need > len(free):
            raise ValueError(
                f"model {cfg.name!r} needs {need} devices but the fleet has "
                f"only {len(free)} free"
            )
        rt = ClusterRuntime(
            cfg,
            params,
            topo=self.topo,
            param_pool=self.param_pool,
            allowed_devices=free[:need],
            n_prefill=n_prefill,
            n_decode=n_decode,
            net=self.net,
            # the fleet subscribes to FlowSim failures once, fleet-wide,
            # and drives teardown/re-grant itself — a per-runtime
            # subscription would double-handle every failure
            failure_subscription=False,
            tracer=self.tracer,
            bridge=self.bridge,
            metrics=self.metrics,
            ledger=self.ledger,
            **runtime_kw,
        )
        t = Tenant(cfg.name, rt, slo_class=slo_class)
        t.stats.bind(self.metrics, f"tenant.{cfg.name}")
        self.tenants[cfg.name] = t
        return t

    # -- request intake ------------------------------------------------------
    def submit(self, model: str, prompt, max_new_tokens: int, now: float) -> int:
        t = self.tenants[model]
        t.note_arrival()
        return t.runtime.submit(prompt, max_new_tokens, now)

    @property
    def n_outstanding(self) -> int:
        return sum(t.runtime.n_outstanding for t in self.tenants.values())

    # -- the control loop ----------------------------------------------------
    def tick(self, now: float) -> dict[str, list[int]]:
        """One fleet iteration; returns rids completed this tick per model."""
        p = self.policy
        dt = 0.0 if self._last_tick is None else max(0.0, now - self._last_tick)
        self._last_tick = now

        # 0. GPU-time accounting: device-seconds occupied by engines
        #    (loading and draining engines hold their device too)
        for t in self.tenants.values():
            held = t.runtime.n_engines * dt
            t.stats.gpu_seconds += held
            self.stats.gpu_seconds += held
        if self.ledger is not None and dt > 0:
            # granted devices no engine occupies yet are still billed to the
            # tenant holding the grant (engine-held time is accrued by each
            # runtime itself inside tick())
            for t in self.tenants.values():
                for dev in t.runtime.allowed_devices or ():
                    if self.topo.device(dev).role is topo_mod.Role.FREE:
                        self.ledger.accrue("allocated_idle", dt, owner=t.name)

        if p.arbitration:
            # 1. grants not consumed by a scale-up flow back to the fleet
            for t in self.tenants.values():
                t.runtime.release_devices()

        # 2. scale-to-zero: drain models idle past the timeout
        if p.scale_to_zero:
            for t in self.tenants.values():
                if t.busy:
                    t.idle_since = None
                elif t.state == T.ACTIVE and t.runtime.n_engines > 0:
                    if t.idle_since is None:
                        t.idle_since = now
                    elif now - t.idle_since >= p.idle_to_zero_s:
                        t.runtime.drain_all()
                        t.state = T.DRAINING
                        self._log(f"[fleet] {t.name}: idle -> draining to zero")

        # 3. arbitration: free devices go to demanders, hottest first (class
        #    weight breaks priority ties); tenants at zero capacity with
        #    waiting work cold-start.  Grants follow placement affinity:
        #    devices in leaves holding a surviving GPU copy first, ranked by
        #    FlowSim-estimated transfer time under current traffic.
        starved: list[tuple[Tenant, int]] = []
        if p.arbitration:
            # SLO-burn tie-break: fleet_health() closes the loop here — at
            # equal pressure a paging tenant outranks a warning one outranks
            # a healthy one (all-zeros when unmonitored or disabled, so the
            # sort degrades to the pressure-only policy)
            slo_rank = self._slo_ranks(now)
            ranked = sorted(
                self.tenants.values(),
                key=lambda t: (t.priority(), slo_rank.get(t.name, 0),
                               t.class_weight),
                reverse=True,
            )
            free = set(self.free_devices())
            for t in ranked:
                want = self._demand(t)
                granted: list[int] = []
                if want > 0 and free:
                    for dev in self._rank_free_for(t, free):
                        if want <= 0:
                            break
                        granted.append(dev)
                        free.discard(dev)
                        want -= 1
                if granted:
                    t.runtime.acquire_devices(granted)
                    self.stats.grants += len(granted)
                    if self.tracer.enabled:
                        self.tracer.instant(
                            "grant", now, cat="fleet", track="fleet",
                            model=t.name, devices=list(granted))
                    self._log(f"[fleet] {t.name}: granted devices {granted}")
                    if self._needs_cold_start(t):
                        host_starts_before = t.runtime.stats.cold_starts_from_host
                        started = t.runtime.cold_start(now)
                        if started:
                            from_host = (
                                t.runtime.stats.cold_starts_from_host > host_starts_before
                            )
                            t.state = T.ACTIVE
                            self.stats.cold_starts += 1
                            if self.tracer.enabled:
                                self.tracer.instant(
                                    "cold_start", now, cat="fleet",
                                    track="fleet", model=t.name,
                                    from_host=from_host)
                            self._log(
                                f"[fleet] {t.name}: cold start ({started} "
                                f"engine(s), source="
                                f"{'host O(1) copy' if from_host else 'GPU copy'})"
                            )
                if want > 0 and (
                    self._needs_cold_start(t)
                    or t.runtime.slo_pressure() >= p.starve_pressure
                ):
                    starved.append((t, want))

            # 4. preemption: starved hot models reclaim devices from idle ones
            for t, want in starved:
                self._preempt_for(t, want, now)

            # 4.5 admission control: fleet-wide saturation (nothing grantable
            # and every demander above the pressure bound) -> shed the
            # lowest-class tenants' excess queue with explicit rejections
            if p.admission_control and not free:
                self._admission_control(now)

        # 5. advance every runtime; finalize drain-to-zero transitions
        finished: dict[str, list[int]] = {}
        for name, t in self.tenants.items():
            finished[name] = t.runtime.tick(now)
            if self.slo_monitor is not None:
                for rid in finished[name]:
                    rec = t.runtime.router.records.get(rid)
                    if rec is None:
                        continue
                    if rec.ttft is not None:
                        self.slo_monitor.observe_ttft(name, now, rec.ttft)
                    for tbt in rec.tbts():
                        self.slo_monitor.observe_tbt(name, now, tbt)
            if t.fully_drained():
                t.state = T.ZERO
                t.idle_since = None
                # defensive: every GPU copy must be reclaimed by now — the
                # pool keeps exactly the single O(1) host copy
                self.param_pool.deactivate(t.name)
                t.runtime.release_devices()
                t.stats.scaled_to_zero += 1
                self.stats.scale_to_zero_events += 1
                self._log(f"[fleet] {t.name}: at zero (host copy only)")
        if self.flight_recorder is not None:
            # after this tick's SLO observations landed, so a page triggered
            # by them dumps in the same tick it escalates
            self.flight_recorder.poll(now)
        return finished

    # -- failure subscription ------------------------------------------------
    def _on_net_event(self, event: NetEvent) -> None:
        if event.kind in FAILURE_KINDS:
            self._handle_failure(event.t)

    def _handle_failure(self, now: float) -> None:
        """React to a link/device/leaf failure the moment the FlowSim emits
        it: revoke grants on dead devices, tear down live-scales that were
        loading onto them (the runtime's abort callback already marked them;
        we retire them NOW instead of waiting for its drain path), re-rank
        placement affinity against the post-failure network, and re-grant +
        restart each lost engine on a surviving leaf — all within the same
        event, so a cold start survives a mid-flight leaf death without
        losing a tick."""
        dead = self.net.dead_devices()
        if not dead:
            return
        for t in self.tenants.values():
            rt = t.runtime
            revoked = rt.revoke_devices(dead)
            self.stats.grant_cancellations += len(revoked)
            lost = rt.fail_devices(dead, now)
            if not lost:
                continue
            # affinity is re-ranked from scratch: dead devices are no longer
            # grantable and estimates route around failed links
            ranked = self._rank_free_for(t, set(self.free_devices()))
            for phase in lost:
                if not ranked:
                    break  # nothing survives; regular arbitration retries
                dev = ranked.pop(0)
                rt.acquire_devices([dev])
                if rt.restart_scale(phase, now, target=dev) is not None:
                    self.stats.failure_regrants += 1
                    if self.tracer.enabled:
                        self.tracer.instant(
                            "failure_regrant", now, cat="fleet",
                            track="fleet", model=t.name, device=dev,
                            phase=phase)
                    self._log(
                        f"[fleet] {t.name}: failure re-grant -> {phase} "
                        f"live-scale on dev {dev}"
                    )

    # -- internals -----------------------------------------------------------
    _SLO_RANK = {"ok": 0, "warn": 1, "page": 2}

    def _slo_ranks(self, now: float) -> dict[str, int]:
        """Per-tenant burn-rate severity for the arbitration tie-break;
        empty (rank 0 for everyone) when unmonitored or disabled."""
        if self.slo_monitor is None or not self.policy.slo_aware_arbitration:
            return {}
        return {
            name: self._SLO_RANK.get(
                self.slo_monitor.tenant_health(name, now).get("status", "ok"), 0)
            for name in self.tenants
        }

    def _rank_free_for(self, t: Tenant, free: set[int]) -> list[int]:
        """Placement-affinity order for granting ``free`` devices to ``t``:
        leaves holding a surviving GPU copy of the model first (the cold
        start / scale-up multicast stays intra-leaf — ROADMAP next-steps
        item), then by the FlowSim's estimated parameter transfer time from
        the nearest source under whatever traffic is currently live."""
        cands = sorted(free)
        gpu_srcs, host = self.param_pool.sources(t.name)
        gpu_srcs = [s for s in gpu_srcs if self.net.device_ok(s)]
        src_devs = gpu_srcs or [
            d.id
            for d in self.topo.devices
            if d.is_host and d.host == host and self.net.device_ok(d.id)
        ]
        if not src_devs:
            return cands
        src_leaves = {self.topo.leaf_of(i) for i in src_devs}

        def nearest_src(dev: int) -> int:
            leaf = self.topo.leaf_of(dev)
            same = [s for s in src_devs if self.topo.leaf_of(s) == leaf]
            return same[0] if same else src_devs[0]

        cands.sort(key=lambda d: 0 if self.topo.leaf_of(d) in src_leaves else 1)
        head = cands[: self.policy.affinity_estimates]
        est = {
            d: self.net.estimate_transfer_time(nearest_src(d), d, t.runtime.model_bytes)
            for d in head
        }
        head.sort(
            key=lambda d: (
                0 if self.topo.leaf_of(d) in src_leaves else 1,
                est[d],
                d,
            )
        )
        return head + cands[len(head):]

    def _admission_control(self, now: float) -> None:
        p = self.policy
        demanders = [t for t in self.tenants.values() if t.queue_depth > 0]
        if not demanders or any(
            t.runtime.slo_pressure() < p.saturation_pressure for t in demanders
        ):
            return  # someone is still comfortably provisioned — not saturated
        low = min(t.class_weight for t in demanders)
        for t in sorted(demanders, key=Tenant.priority):
            if t.class_weight != low:
                continue  # only the lowest SLO class present is shed
            over = t.queue_depth - p.shed_queue_depth
            if over <= 0:
                continue
            shed = t.runtime.shed_queued(over, now)
            t.stats.rejected += len(shed)
            self.stats.rejections += len(shed)
            self._log(
                f"[fleet] {t.name}: saturation -> shed {len(shed)} queued "
                f"request(s) ({t.slo_class} class)"
            )

    def _needs_cold_start(self, t: Tenant) -> bool:
        rt = t.runtime
        n_prov = rt.pool.n_provisioned(P.PREFILL) + rt.pool.n_provisioned(P.DECODE)
        return n_prov == 0 and t.queue_depth > 0

    def _demand(self, t: Tenant) -> int:
        """Devices this tenant wants from arbitration this tick."""
        p = self.policy
        rt = t.runtime
        if self._needs_cold_start(t):
            return 2  # one prefill + one decode seat
        n_pre = rt.pool.n_provisioned(P.PREFILL)
        n_dec = rt.pool.n_provisioned(P.DECODE)
        if (n_pre + n_dec == 0) or rt.frozen:
            return 0  # parked (and nothing queued), or held static
        # per-phase: the runtime's own policy caps instances per phase, so
        # granting a device its binding phase can't use just ping-pongs it
        # through release_devices() every tick
        cap = rt.autoscaler.policy.max_instances
        pressures = rt.autoscaler.phase_pressures(n_pre, n_dec)
        want = 0
        for pressure, n, head in zip(pressures, (n_pre, n_dec), (cap - n_pre, cap - n_dec)):
            if head <= 0:
                continue
            if n == 0 and rt.n_outstanding > 0:
                # a half-seated tenant (e.g. a cold start that only got one
                # device) reads zero pressure on the empty phase — but work
                # cannot flow without at least one instance of each
                want += 1
            elif pressure <= p.grow_pressure:
                continue
            elif not math.isfinite(pressure):
                want += head
            else:
                want += min(head, math.ceil((pressure - 1.0) * max(n, 1)) or 1)
        return min(p.max_grant_per_tick, want)

    def _preempt_for(self, starving: Tenant, want: int, now: float) -> None:
        """Idle-model preemption: drain capacity from the lowest-priority
        tenants so ``starving`` finds free devices in a following tick."""
        victims = sorted(self.tenants.values(), key=Tenant.priority)
        for v in victims:
            if want <= 0:
                break
            if v is starving or v.runtime.n_engines == 0:
                continue
            if v.priority() >= self.policy.preempt_pressure:
                break  # sorted ascending: nobody cheaper remains
            if not v.busy and self.policy.scale_to_zero:
                n = v.runtime.drain_all()
                if n:
                    v.state = T.DRAINING
                    v.stats.preempted += 1
                    self.stats.preemptions += 1
                    want -= n
                    self._log(
                        f"[fleet] {v.name}: preempted (drain all {n}) for {starving.name}"
                    )
            else:
                dev = v.runtime.preempt_one(now)
                if dev is not None:
                    v.stats.preempted += 1
                    self.stats.preemptions += 1
                    want -= 1
                    self._log(
                        f"[fleet] {v.name}: preempted dev {dev} for {starving.name}"
                    )

    # -- reporting -----------------------------------------------------------
    def fleet_health(self, now: float | None = None) -> dict:
        """SLO summary (per-tenant quantiles, attainment, burn rates) from
        the attached :class:`~repro.obs.slo.SLOMonitor`; empty dict when the
        fleet runs unmonitored.  No longer observe-only: per-tenant status
        feeds the arbitration tie-break (``slo_aware_arbitration``) and a
        fleet-level ``page`` triggers the flight recorder's incident dump."""
        if self.slo_monitor is None:
            return {}
        return self.slo_monitor.fleet_health(now if now is not None
                                             else self._last_tick)

    def slo_reports(self):
        return {name: t.runtime.router.slo_report() for name, t in self.tenants.items()}

    def attainment(self, ttft_slo: float, tbt_slo: float) -> float:
        """Fleet-wide fraction of requests within an *absolute* SLO — the
        cross-system comparison metric (the per-router 5x-average SLO is
        self-referential, so it cannot compare two systems at 'equal SLO')."""
        ok = n = 0
        for t in self.tenants.values():
            for r in t.runtime.router.records.values():
                if r.ttft is None:
                    continue
                n += 1
                if r.ttft <= ttft_slo and all(b <= tbt_slo for b in r.tbts()):
                    ok += 1
        return ok / n if n else 1.0

    def run_until_done(self, clock, *, max_ticks: int = 100_000) -> bool:
        """Drive ticks until every submitted request completed."""
        for _ in range(max_ticks):
            if self.n_outstanding == 0:
                return True
            self.tick(clock())
        return self.n_outstanding == 0
