"""One model's seat in the MaaS fleet (paper §1, §5.3).

A :class:`Tenant` wraps a per-model :class:`ClusterRuntime` with the state
the fleet scheduler arbitrates on: lifecycle (ACTIVE → DRAINING → ZERO →
ACTIVE again on cold start), how long the model has been idle, and the
accounting the paper's Fig. 18 comparison needs (GPU-seconds actually
occupied, cold starts, preemptions suffered).

Scale-to-zero is what makes the fleet *serverless*: a parked model holds no
accelerator at all — only its single O(1) host-DRAM copy in the shared
:class:`ParameterPool` — and rejoins in seconds via a multicast cold start.
"""

from __future__ import annotations

import dataclasses

from repro.obs.metrics import StatBlock
from repro.serving.disagg.runtime import ClusterRuntime

ACTIVE = "active"  # has engines (possibly some draining) and may serve
DRAINING = "draining"  # fleet decided scale-to-zero; engines finishing up
ZERO = "zero"  # no engines, no devices — only the O(1) host copy remains

# SLO classes: a latency-tier tenant's pressure is weighted up in fleet
# arbitration (it wins ties for free devices and is shed LAST under
# admission control); throughput-tier tenants tolerate queueing.
LATENCY = "latency"
THROUGHPUT = "throughput"
CLASS_WEIGHTS = {LATENCY: 4.0, THROUGHPUT: 1.0}


@dataclasses.dataclass
class TenantStats(StatBlock):
    # cold starts live on runtime.stats (the runtime performs them); here is
    # only what the FLEET decides about this tenant
    scaled_to_zero: int = 0
    preempted: int = 0
    rejected: int = 0  # requests shed by fleet admission control
    gpu_seconds: float = 0.0  # device-seconds actually occupied by engines


class Tenant:
    """Per-model fleet seat: runtime + lifecycle + arbitration signals."""

    def __init__(
        self,
        name: str,
        runtime: ClusterRuntime,
        slo_class: str = LATENCY,
        class_weight: float | None = None,
    ):
        self.name = name
        self.runtime = runtime
        self.state = ACTIVE
        self.idle_since: float | None = None
        if class_weight is None and slo_class not in CLASS_WEIGHTS:
            # a typo'd tier would silently land in the lowest (sheddable)
            # class — an SLO inversion the operator never asked for
            raise ValueError(
                f"unknown slo_class {slo_class!r}; expected one of "
                f"{sorted(CLASS_WEIGHTS)} (or pass class_weight explicitly)"
            )
        self.slo_class = slo_class
        self.class_weight = (
            CLASS_WEIGHTS[slo_class] if class_weight is None else class_weight
        )
        self.stats = TenantStats()

    # -- arbitration signals -------------------------------------------------
    @property
    def queue_depth(self) -> int:
        return len(self.runtime.router.queue)

    @property
    def busy(self) -> bool:
        return self.runtime.n_outstanding > 0

    def priority(self) -> float:
        """Fleet-arbitration priority: class weight × SLO pressure × queue
        depth — the latency tier outranks the throughput tier at equal load.

        A parked (or fully drained) tenant with waiting work outranks every
        warm tenant — cold starts are the most latency-critical grant the
        fleet makes (the request is already ageing against its TTFT SLO);
        among cold-starters the fleet tie-breaks on class weight."""
        if self.runtime.n_serving == 0 and self.queue_depth > 0:
            return float("inf")
        return self.class_weight * self.runtime.slo_pressure() * (1.0 + self.queue_depth)

    # -- lifecycle helpers ---------------------------------------------------
    def note_arrival(self) -> None:
        self.idle_since = None
        if self.state == DRAINING:
            # work arrived mid-drain: the tenant is live again (remaining
            # drains proceed; the autoscaler re-grows capacity as needed)
            self.state = ACTIVE

    def fully_drained(self) -> bool:
        return (
            self.state == DRAINING
            and self.runtime.n_engines == 0
            and self.runtime.n_outstanding == 0
        )

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (
            f"Tenant({self.name!r}, {self.state}, engines={self.runtime.n_engines}, "
            f"queue={self.queue_depth})"
        )
