"""PD-disaggregated serving demo on a real-trace burst (paper §5.4).

A BurstGPT-shaped arrival burst (repro.serving.traces) hits a disaggregated
cluster of prefill + decode engine pools.  Watch the §5.4 policy work:

  * finished prefills freeze their KV pages and migrate them to a decode
    instance over the modelled compute network;
  * the burst trips the autoscaler: decode capacity is raised by *mutating*
    a prefill instance in place (parameters already resident — zero bytes
    move, no incast with the KVCache migration traffic) while a replacement
    prefill live-scales on a spare device;
  * when the burst passes, the scale-down timeout drains the extra
    instances and frees their devices.

    PYTHONPATH=src python examples/serve_disagg.py
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import topology as tp
from repro.core.autoscaler import PolicyConfig
from repro.models import transformer as TF
from repro.serving import traces
from repro.serving.disagg import ClusterRuntime

ARCH = "granite-8b"
PROMPT, GEN = 24, 8
TRACE_SECONDS = 12.0  # burstgpt's first burst, compressed


def main() -> None:
    cfg = get_config(ARCH, reduced=True)
    params = TF.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)

    # arrival *times* from the BurstGPT shape; token lengths kept tiny so
    # the demo runs in seconds on CPU
    tr = traces.burstgpt(duration=60.0, base_rate=0.4, burst_every=60.0, seed=0)
    arrivals = sorted(t * TRACE_SECONDS / 60.0 for t, _, _ in tr)[:32]

    topo = tp.add_host_sources(tp.make_cluster(2, 4, bw_gbps=100.0))
    rt = ClusterRuntime(
        cfg,
        params,
        topo=topo,
        policy=PolicyConfig(max_instances=4, kv_upper=0.5, scale_down_timeout_s=0.5),
        n_prefill=2,
        n_decode=1,
        n_slots=4,
        max_seq=PROMPT + GEN + 8,
        model_bytes=get_config(ARCH).approx_params() * 2,
        prefill_capacity_tps=2000.0,
        decode_capacity_tps=200.0,
        verbose=True,
    )

    t0 = time.perf_counter()
    clock = lambda: time.perf_counter() - t0
    pending = list(arrivals)
    for _ in range(100_000):
        if not pending and rt.n_outstanding == 0:
            break
        now = clock()
        while pending and pending[0] <= now:
            pending.pop(0)
            prompt = rng.integers(0, cfg.vocab_size, size=PROMPT).astype(np.int32)
            rt.submit(prompt, GEN, now)
        rt.tick(now)
    else:
        raise RuntimeError(f"tick budget exhausted with {rt.n_outstanding} outstanding")

    rep = rt.router.slo_report()
    handoffs, gapped = rt.router.handoff_report()
    s = rt.stats
    print(
        f"\nserved {rep.n} requests in {clock():.2f}s  "
        f"mean_ttft {rep.mean_ttft*1e3:.0f}ms attainment {rep.attainment:.0%}"
    )
    print(
        f"migrations {s.migrations}  mutations {s.mutations} "
        f"(param bytes moved: {s.mutation_param_bytes})  "
        f"replacement live-scales {s.live_scaled_prefill}  "
        f"scale-downs {s.scale_downs}  handoffs {handoffs} gapped {gapped}"
    )


if __name__ == "__main__":
    main()
