"""Serverless multi-model MaaS demo: scale-to-zero + multicast cold start.

Three models share one 8-device fleet under the MaaS control plane
(repro.serving.maas).  The script walks the serverless lifecycle the paper
builds toward (§1):

  phase 1 — a burst hits the hot model; the fleet grants it the free
            devices and its runtime live-scales (§5.4 policy inside);
  phase 2 — the cold models sit idle past the timeout: they drain, free
            every accelerator, and park at *zero* — the shared
            ParameterPool holds exactly one host-DRAM copy each (O(1));
  phase 3 — a late request arrives for a parked model: the fleet grants
            seats and the model cold-starts by re-multicasting parameters
            from its O(1) host copy, then serves.

A virtual clock drives the fleet so the run is deterministic.

    PYTHONPATH=src python examples/serve_maas.py
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_config
from repro.core import topology as tp
from repro.core.autoscaler import PolicyConfig
from repro.models import transformer as TF
from repro.serving.maas import FleetPolicy, FleetScheduler, ZERO

ARCHS = ["granite-8b", "qwen1.5-4b", "minicpm3-4b"]
PROMPT, GEN = 16, 6
TICK = 0.01


def main() -> None:
    topo = tp.add_host_sources(tp.make_cluster(2, 4, bw_gbps=100.0))
    fleet = FleetScheduler(topo, policy=FleetPolicy(idle_to_zero_s=0.5), verbose=True)

    cfgs = {}
    rng = np.random.default_rng(0)
    for i, arch in enumerate(ARCHS):
        cfg = get_config(arch, reduced=True)
        cfgs[cfg.name] = cfg
        fleet.add_model(
            cfg,
            TF.init_params(jax.random.PRNGKey(i), cfg),
            n_prefill=1,
            n_decode=1,
            n_slots=2,
            max_seq=PROMPT + GEN + 8,
            model_bytes=int(200e6),  # ~16 ms modelled multicast on 100 Gbps
            prefill_capacity_tps=400.0,
            decode_capacity_tps=60.0,
            policy=PolicyConfig(max_instances=3, kv_upper=0.5, scale_down_timeout_s=0.4),
        )
    hot, _, cold = list(cfgs)

    def submit(model: str, now: float) -> None:
        prompt = rng.integers(0, cfgs[model].vocab_size, size=PROMPT).astype(np.int32)
        fleet.submit(model, prompt, GEN, now)

    def run_until_idle(t: float) -> float:
        while fleet.n_outstanding:
            t += TICK
            fleet.tick(t)
            assert fleet.param_pool.invariant_ok()
        return t

    print(f"== phase 1: burst of 8 requests on the hot model ({hot})")
    t = 0.0
    for _ in range(8):
        submit(hot, t)
    t = run_until_idle(t)
    print(f"   done at t={t:.2f}s, hot model holds "
          f"{fleet.tenants[hot].runtime.n_engines} engines\n")

    print("== phase 2: everyone idle -> fleet drains all models to zero")
    while not all(x.state == ZERO for x in fleet.tenants.values()):
        t += TICK
        fleet.tick(t)
        assert fleet.param_pool.invariant_ok()
    free = len(topo.spares())
    cache = {h: f"{b/1e6:.0f}MB" for h, b in fleet.param_pool.host_cache_bytes().items()}
    print(f"   at t={t:.2f}s all {len(ARCHS)} models are at zero; "
          f"{free}/8 accelerators free; host cache per host: {cache}\n")

    print(f"== phase 3: late request for a parked model ({cold}) -> cold start")
    submit(cold, t)
    t_cold = t
    t = run_until_idle(t)
    tc = fleet.tenants[cold]
    rep = tc.runtime.router.slo_report()
    print(
        f"   served at t={t:.2f}s: cold-start TTFT {rep.mean_ttft*1e3:.0f}ms "
        f"(submitted t={t_cold:.2f}s), multicast source: "
        f"{'O(1) host copy' if tc.runtime.stats.cold_starts_from_host else 'GPU copy'}\n"
    )

    s = fleet.stats
    print(
        f"fleet totals: {s.grants} grants, {s.cold_starts} cold starts, "
        f"{s.scale_to_zero_events} scale-to-zero events, "
        f"{s.gpu_seconds:.2f} GPU-seconds occupied"
    )
    assert s.cold_starts >= 1 and s.scale_to_zero_events >= len(ARCHS)


if __name__ == "__main__":
    main()
