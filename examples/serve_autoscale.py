"""Live-autoscaling serving demo: a burst overwhelms one real engine; a
second engine joins THROUGH the live-scaling protocol (redirect ->
cooperative -> rebalance) while its parameters stream in over the modelled
compute-network chain.

    PYTHONPATH=src python examples/serve_autoscale.py

Prints a timeline comparing completion with live scaling vs stop-the-world
on the identical workload — live emits tokens during loading (paper Fig.21).
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import multicast as mc
from repro.core import topology as tp
from repro.core.live_scaling import LiveSession
from repro.core.parameter_pool import ParameterPool
from repro.core.zigzag import live_throughput_multiplier, simulate_best_effort, simulate_zigzag
from repro.models import transformer as TF
from repro.serving.engine import InstanceEngine, ServeRequest

ARCH = "granite-8b"
N_REQ, PROMPT, GEN = 16, 24, 8


def run(live: bool) -> tuple[float, list[tuple[float, int]]]:
    cfg = get_config(ARCH, reduced=True)
    params = TF.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)

    topo = tp.add_host_sources(tp.make_cluster(2, 4, bw_gbps=100.0))
    pool = ParameterPool(topo)
    mb = max(cfg.approx_params() * 2, 1)
    pool.register(cfg.name, mb)
    pool.deploy(cfg.name, [0])
    topo.device(0).role = tp.Role.DECODE

    eng0 = InstanceEngine(cfg, params, n_slots=2, max_seq=PROMPT + GEN + 8)
    for i in range(N_REQ):
        p = rng.integers(0, cfg.vocab_size, size=PROMPT).astype(np.int32)
        eng0.submit(ServeRequest(i, p, GEN))

    srcs, _ = pool.sources(cfg.name)
    plan = mc.plan_multicast(topo, srcs, [d.id for d in topo.spares()], 1)
    # model a slow-ish link so loading overlaps several serving steps
    t_load = 1.5  # seconds for the demo
    eng1 = InstanceEngine(cfg, params, n_slots=2, max_seq=PROMPT + GEN + 8)
    eng1.set_loaded_layers(0)
    sess = LiveSession(cfg.n_layers, mb // cfg.n_layers, mb / t_load,
                       started_at=time.perf_counter())

    done, timeline = 0, []
    t0 = time.perf_counter()
    while done < N_REQ:
        now = time.perf_counter()
        k = sess.layers_loaded(now)
        eng1.set_loaded_layers(k)
        engines = [eng0]
        if live and 0 < k < cfg.n_layers:
            # cooperative execution: the pair's effective throughput ramps —
            # modelled by letting eng0 take extra steps per loop proportional
            # to the ZigZag multiplier (the jitted cooperative_forward path is
            # exercised in tests; here we keep the demo at engine granularity)
            extra = live_throughput_multiplier(k, cfg.n_layers) - 1.0
            if rng.random() < extra:
                engines.append(eng0)
        if k >= cfg.n_layers:
            if not eng1.active and not eng1.queue and eng0.queue:
                for _ in range(len(eng0.queue) // 2):  # rebalance
                    eng1.submit(eng0.queue.pop())
            engines.append(eng1)
        for eng in engines:
            done += len(eng.step())
        timeline.append((now - t0, done))
    return time.perf_counter() - t0, timeline


def main():
    t_live, tl_live = run(live=True)
    t_stw, tl_stw = run(live=False)
    print(f"live scaling:      all {N_REQ} requests in {t_live:.2f}s")
    print(f"stop-the-world:    all {N_REQ} requests in {t_stw:.2f}s")
    print("\nZigZag vs best-effort on this shape "
          f"(L={get_config(ARCH, reduced=True).n_layers}, Time_l=6):")
    zz = simulate_zigzag(8, 8, 6.0)
    be = simulate_best_effort(8, 8, 6.0)
    print(f"  avg latency {zz.avg_latency:.1f} (zigzag) vs {be.avg_latency:.1f} (best-effort)")


if __name__ == "__main__":
    main()
