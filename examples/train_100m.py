"""End-to-end driver: train a ~100M-parameter granite-family model for a few
hundred steps on the synthetic pipeline, with checkpoints (deliverable b).

    PYTHONPATH=src python examples/train_100m.py [--steps 300]

Uses the same code path as the production launcher (repro.launch.train):
AdamW + cosine schedule, grad accumulation, remat scan, atomic checkpoints.
On CPU this takes a few minutes at the default 300 steps; loss drops from
~8.5 to well below the unigram entropy of the synthetic stream.
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import make_batch
from repro.models import transformer as TF
from repro.training.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.training.optimizer import AdamWConfig, adamw_init
from repro.training.train_step import build_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/blitz_train_100m")
    args = ap.parse_args()

    # ~100M params: a scaled-down granite (8 layers, d=768, ff=2048)
    cfg = get_config("granite-8b").replace(
        name="granite-100m", n_layers=8, d_model=768, n_heads=12, n_kv_heads=4,
        d_ff=2048, vocab_size=32_000, microbatches=1, remat=True,
        sharding_overrides=None,
    )
    opt_cfg = AdamWConfig(lr=6e-4, warmup_steps=30, total_steps=args.steps)
    params = TF.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params, opt_cfg)
    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"{cfg.name}: {n/1e6:.1f}M params, batch {args.batch} x seq {args.seq}")

    start = 0
    if latest_step(args.ckpt) is not None:
        state, start = restore_checkpoint(args.ckpt, {"params": params, "opt": opt})
        params, opt = state["params"], state["opt"]
        print(f"resumed from step {start}")

    step_fn = jax.jit(build_train_step(cfg, opt_cfg), donate_argnums=(0, 1))
    t0, first_loss = time.perf_counter(), None
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, args.batch, args.seq, step=step).items()}
        params, opt, m = step_fn(params, opt, batch)
        if step % 20 == 0 or step == args.steps - 1:
            loss = float(m["loss"])
            first_loss = first_loss if first_loss is not None else loss
            tok_s = (step - start + 1) * args.batch * args.seq / (time.perf_counter() - t0)
            print(f"step {step:4d}  loss {loss:.4f}  lr {float(m['lr']):.2e}  tok/s {tok_s:,.0f}")
        if (step + 1) % 100 == 0:
            path = save_checkpoint(args.ckpt, step + 1, {"params": params, "opt": opt})
            print(f"  checkpoint -> {path}")

    print(f"\nloss {first_loss:.3f} -> {float(m['loss']):.3f} over {args.steps - start} steps")


if __name__ == "__main__":
    main()
