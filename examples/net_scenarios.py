"""Walkthrough of the flow-level network data plane (`repro.net`).

Five short acts on one 2-leaf cluster:

  1. a multicast scale-up executes as flows and matches the plan's
     dedicated-link estimate;
  2. a KV-cache drain into the same targets slows it — the §5.4 incast
     emerging from max-min sharing, not from a hand-written model;
  3. a degraded downlink stretches everything (scenario knob);
  4. a device failure aborts the parameter stream mid-transfer and the
     abort callback re-plans from the surviving sources;
  5. on a 2-plane spine, a failed uplink plane re-routes flows instead.

    PYTHONPATH=src python examples/net_scenarios.py
"""

import math

from repro.core import multicast as mc
from repro.core import topology as tp
from repro.net import LEAF_DOWN, LEAF_UP, Flow, FlowKind, FlowSim, MulticastExecution

MODEL_BYTES = int(16e9)  # 8B model in bf16
KV_BYTES = int(2e9)


def build():
    topo = tp.add_host_sources(tp.make_cluster(4, 4, bw_gbps=100.0))
    for i in (0, 1):  # decode instances in leaf 0 hold the model (egress free)
        topo.device(i).model = "m"
        topo.device(i).role = tp.Role.DECODE
    tgts = [d.id for d in topo.spares() if d.leaf == 1][:4]
    return topo, [0, 1], tgts


def act(title):
    print(f"\n=== {title}")


def main():
    act("1. dedicated links: flows reproduce the analytic chain time")
    topo, srcs, tgts = build()
    plan = mc.plan_multicast(topo, srcs, tgts, len(tgts))
    sim = FlowSim(topo)
    ex = MulticastExecution(plan, MODEL_BYTES)
    ex.start(sim, 0.0)
    sim.advance_to(1e6)
    print(f"   plan estimate {plan.transfer_seconds(MODEL_BYTES):.2f}s, "
          f"realized {ex.done_at:.2f}s over {len(ex.flows)} flows")

    act("2. + KV drain into the same targets: incast emerges")
    topo, srcs, tgts = build()
    plan = mc.plan_multicast(topo, srcs, tgts, len(tgts))
    sim = FlowSim(topo)
    ex = MulticastExecution(plan, MODEL_BYTES)
    ex.start(sim, 0.0)
    kv = [sim.start(Flow(FlowKind.KV_MIGRATION, 2 + k, tgts[k % len(tgts)],
                         float(KV_BYTES)), 0.0) for k in range(4)]
    sim.advance_to(1e6)
    print(f"   scale-up now {ex.done_at:.2f}s; last KV page lands at "
          f"{max(f.finished_at for f in kv):.2f}s")

    act("3. degraded downlink (x0.1): both consumers stretch")
    topo, srcs, tgts = build()
    plan = mc.plan_multicast(topo, srcs, tgts, len(tgts))
    sim = FlowSim(topo)
    sim.degrade_link((LEAF_DOWN, 1, 0), 0.1)
    ex = MulticastExecution(plan, MODEL_BYTES)
    ex.start(sim, 0.0)
    sim.advance_to(1e6)
    print(f"   scale-up {ex.done_at:.2f}s on the degraded path")

    act("4. device failure mid-transfer: abort callback -> re-plan")
    topo, srcs, tgts = build()
    plan = mc.plan_multicast(topo, srcs, tgts, len(tgts))
    sim = FlowSim(topo)
    events = []
    ex = MulticastExecution(plan, MODEL_BYTES,
                            on_abort=lambda e, t: events.append(t))
    ex.start(sim, 0.0)
    sim.fail_device(tgts[0], 0.2)
    print(f"   aborted at t={events[0]:.2f}s; surviving spares: "
          f"{[d.id for d in topo.spares() if sim.device_ok(d.id)][:6]}...")
    replan_tgts = [i for i in tgts if sim.device_ok(i)]
    plan2 = mc.plan_multicast(topo, srcs, replan_tgts, len(replan_tgts))
    ex2 = MulticastExecution(plan2, MODEL_BYTES)
    ex2.start(sim, 0.2)
    sim.advance_to(1e6)
    print(f"   re-planned onto {len(replan_tgts)} healthy targets, "
          f"done at t={ex2.done_at:.2f}s")

    act("5. dual-plane spine: a failed uplink plane re-routes")
    topo, srcs, tgts = build()
    sim = FlowSim(topo, spine_planes=2)
    f = sim.start(Flow(FlowKind.COLD_START, srcs[0], tgts[0], float(MODEL_BYTES)), 0.0)
    plane = next(l.key for l in f.path if l.key[0] == LEAF_UP)
    aborted = sim.fail_link(plane, 0.3)
    assert aborted == [] and not f.aborted
    sim.advance_to(1e6)
    print(f"   plane {plane} failed at 0.3s; flow re-routed and finished at "
          f"{f.finished_at:.2f}s (no abort)")

    print("\nall five scenarios behaved as modelled")


if __name__ == "__main__":
    main()
