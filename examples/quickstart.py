"""Quickstart: the BlitzScale mechanism in ~60 lines.

1. build a cluster topology + the O(1) global parameter pool,
2. generate an interference-free multicast plan (Algorithm 11),
3. see why the serial chain makes scale time independent of receiver count,
4. watch live (ZigZag) scaling beat best-effort on the paper's Fig.15 example.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import multicast as mc
from repro.core import topology as tp
from repro.core.parameter_pool import ParameterPool
from repro.core.zigzag import simulate_best_effort, simulate_zigzag, solve_pipeline_ilp


def main() -> None:
    # -- 1. a 4-host x 8-GPU cluster with NVLink scale-up + 100G RDMA -------
    topo = tp.add_host_sources(tp.make_cluster(n_hosts=4, devs_per_host=8))
    pool = ParameterPool(topo)
    model, size = "llama3-8b", 16_000_000_000
    pool.register(model, size)  # exactly ONE host-DRAM copy cluster-wide

    # one serving instance is already deployed (a decode instance: egress free)
    pool.deploy(model, [0])
    topo.device(0).role = tp.Role.DECODE

    # -- 2. a burst arrives: scale 6 new instances --------------------------
    gpu_srcs, host_copy = pool.sources(model)
    spares = [d.id for d in topo.spares()]
    plan = mc.plan_multicast(topo, gpu_srcs, spares, n=6)
    assert mc.validate_plan(topo, plan) == [], "interference-free by construction"
    print(f"plan: {len(plan.chains)} chain(s) in {plan.gen_seconds*1e3:.2f} ms")
    for i, ch in enumerate(plan.chains):
        path = " -> ".join(str(n.device_ids) for n in ch.nodes)
        print(f"  chain {i}: {path}  bottleneck {ch.bottleneck_gbps:.0f} Gbps")

    # -- 3. chain time is independent of the receiver count -----------------
    t = plan.transfer_seconds(size)
    print(f"scale 6 instances over the compute network: {t*1e3:.0f} ms "
          f"(1 instance would take {mc.chain_time_model(size, 100.0, 1)*1e3:.0f} ms — same!)")
    print(f"SSD at 10 Gbps would take {size / (10e9/8):.1f} s")

    # -- 4. live ZigZag scaling (paper Fig.15: 7 requests, 7 layers, Time_l=6)
    be = simulate_best_effort(7, 7, 6.0)
    zz = simulate_zigzag(7, 7, 6.0)
    ilp = solve_pipeline_ilp(7, 7, 6.0)
    print("\nlive scaling (7 layers, load=6x exec):")
    print(f"  best-effort avg latency {be.avg_latency:.1f}, makespan {be.makespan:.0f}")
    print(f"  ZigZag      avg latency {zz.avg_latency:.1f}, makespan {zz.makespan:.0f}")
    print(f"  exact ILP   avg latency {ilp.avg_latency:.1f} (solved in {ilp.solve_ms:.1f} ms)")


if __name__ == "__main__":
    main()
